"""Roofline table from the dry-run results (§Roofline deliverable).

Reads ``benchmarks/results/dryrun_<tag>.json`` (written incrementally by
repro.launch.dryrun) and renders the per-(arch × shape × mesh) three-term
table: compute / memory / collective seconds, dominant term, MODEL_FLOPS
ratio, roofline fraction, HBM fit — plus a one-line "what would move the
dominant term" note derived from the dominant term and the cell kind.
"""
from __future__ import annotations

import json
import os
from typing import Optional

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(tag: str = "baseline") -> dict:
    path = os.path.join(RESULTS, f"dryrun_{tag}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def suggestion(rec: dict) -> str:
    r = rec.get("roofline", {})
    dom = r.get("dominant", "?")
    kind = rec.get("kind", "?")
    if dom == "memory" and kind == "train":
        return ("fuse attention softmax chain into the Pallas flash "
                "kernel (S^2 tensors stay in VMEM)")
    if dom == "memory":
        return ("decode is weight/cache-streaming bound: int8 KV cache "
                "or wider batch raises arithmetic intensity")
    if dom == "collective":
        return ("reduce TP resharding: bf16 grad reduction + "
                "head-aligned shardings; overlap per MXDAG plan")
    return "increase per-chip batch or reduce remat recompute"


def rows(tag: str = "baseline") -> list[dict]:
    out = []
    for key, rec in sorted(load(tag).items()):
        if rec.get("skipped"):
            out.append({"cell": key, "skipped": rec["skipped"]})
            continue
        if not rec.get("ok"):
            out.append({"cell": key,
                        "error": rec.get("error", "?")[:80]})
            continue
        r = rec["roofline"]
        out.append({
            "cell": key,
            "kind": rec["kind"],
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "dominant": r["dominant"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_fraction": r["roofline_fraction"],
            "fits_hbm": rec["memory"]["fits_hbm"],
            "peak_gb": rec["memory"]["peak_estimate_bytes"] / 2**30,
            "suggestion": suggestion(rec),
        })
    return out


def table(tag: str = "baseline") -> str:
    lines = [f"{'cell':46s} {'kind':8s} {'compute':>9s} {'memory':>9s} "
             f"{'collect':>9s} {'dom':10s} {'useful':>7s} {'frac':>6s} "
             f"{'HBM':>5s}"]
    for r in rows(tag):
        if "skipped" in r:
            lines.append(f"{r['cell']:46s} SKIP  {r['skipped'][:60]}")
            continue
        if "error" in r:
            lines.append(f"{r['cell']:46s} FAIL  {r['error']}")
            continue
        lines.append(
            f"{r['cell']:46s} {r['kind']:8s} {r['compute_s']:9.3f} "
            f"{r['memory_s']:9.3f} {r['collective_s']:9.3f} "
            f"{r['dominant']:10s} {r['useful_ratio']:7.3f} "
            f"{r['roofline_fraction']:6.3f} "
            f"{'ok' if r['fits_hbm'] else 'OVER':>5s}")
    return "\n".join(lines)


def bench_rows(tag: str = "baseline"):
    """(name, value, derived) rows for the CSV driver."""
    out = []
    for r in rows(tag):
        if "skipped" in r or "error" in r:
            continue
        name = r["cell"].replace("|", ".")
        out.append((f"roofline.{name}.bound_s",
                    max(r["compute_s"], r["memory_s"], r["collective_s"]),
                    f"dominant={r['dominant']} frac="
                    f"{r['roofline_fraction']:.3f} fits={r['fits_hbm']}"))
    return out


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "baseline"))
