"""Cluster resource model: hosts with processor pools and NICs.

Resource naming convention (matches ``MXTask.resources()``):

- ``"<host>.<proc>"``   — a processor pool with an integer slot count
  (compute tasks occupy one slot exclusively, non-preemptively),
- ``"<host>.nic_out"`` / ``"<host>.nic_in"`` — NIC directions with a float
  capacity (flows share them; rate allocation is policy-driven and
  preemptible, reflecting the paper's observation that network tasks cannot
  be isolated the way compute tasks can).

Capacities are normalized: a flow of ``size`` seconds completes in ``size``
seconds when allocated rate 1.0.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.graph import MXDAG
from repro.core.task import TaskKind


@dataclasses.dataclass(frozen=True)
class Host:
    name: str
    procs: Mapping[str, int] = dataclasses.field(
        default_factory=lambda: {"cpu": 1})
    nic_in: float = 1.0
    nic_out: float = 1.0


class Cluster:
    def __init__(self, hosts: list[Host]) -> None:
        self.hosts = {h.name: h for h in hosts}

    @classmethod
    def homogeneous(cls, names: list[str], *, procs: Mapping[str, int] | None = None,
                    nic: float = 1.0) -> "Cluster":
        return cls([Host(n, procs=dict(procs or {"cpu": 1}),
                         nic_in=nic, nic_out=nic) for n in names])

    @classmethod
    def for_graph(cls, g: MXDAG, *, nic: float = 1.0) -> "Cluster":
        """Build a sufficient homogeneous cluster for a graph's placements."""
        names: set[str] = set()
        procs: dict[str, int] = {}
        for t in g:
            if t.kind is TaskKind.COMPUTE:
                names.add(t.host)  # type: ignore[arg-type]
                procs[t.proc] = 1
            else:
                names.add(t.src)   # type: ignore[arg-type]
                names.add(t.dst)   # type: ignore[arg-type]
        procs = procs or {"cpu": 1}
        return cls.homogeneous(sorted(names), procs=procs, nic=nic)

    def slots(self, resource: str) -> int:
        host, pool = resource.rsplit(".", 1)
        return int(self.hosts[host].procs.get(pool, 0))

    def bandwidth(self, resource: str) -> float:
        host, direction = resource.rsplit(".", 1)
        h = self.hosts[host]
        return h.nic_out if direction == "nic_out" else h.nic_in
