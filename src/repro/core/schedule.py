"""MXDAG schedulers (paper §4).

- :class:`FairShareScheduler` — the network-aware-DAG baseline of Fig. 1(b):
  every task starts as soon as its dependencies allow; NIC bandwidth is
  max-min fair-shared; no flow-level priorities; no pipelining decisions.

- :class:`CoflowConfig` — the §2.2 baseline: flows grouped into coflows with
  synchronized start, MADD-coupled rates and all-or-nothing gating.

- :class:`MXDAGScheduler` — Principle 1: prioritize the critical path within
  any copath (without letting non-critical paths exceed the critical path),
  and enable pipelining on an edge only when it shrinks the makespan
  (the Fig. 3 analysis, automated as a greedy what-if loop).  With a
  :class:`PlacementScheduler` stage, *where* logical tasks run and *which
  path* each flow takes become further decisions in the same loop.

- :class:`PlacementScheduler` — slack-guided greedy placement of logical
  (unbound) tasks onto cluster hosts, avoiding oversubscribed uplinks,
  refined by memoized what-if DES runs.

- :class:`AltruisticMultiScheduler` — Principle 2: a job delays/demotes its
  non-critical tasks, bounded by their slack, to donate resources to other
  jobs' critical paths without extending its own completion time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import arrayanalytic
from repro.core.cluster import Cluster
from repro.core.fabric import nic_in, nic_out
from repro.core.graph import MXDAG
from repro.core.parallel import effective_workers, trial_map
from repro.core.simulator import SimResult, simulate
from repro.core.task import TaskKind

# priority classes (lower value = more urgent)
CRITICAL = 0.0
NONCRITICAL = 1.0
ALTRUIST_DEMOTED = 2.0


@dataclasses.dataclass
class Schedule:
    """Everything needed to execute a scheduling decision in the DES.

    A Schedule carries every *kind* of decision the co-scheduler can make:

    - **priorities** — per-task priority classes (Principle 1: critical
      path first; Principle 2: altruistic demotion), consumed by the
      ``"priority"`` policy's strict-class waterfill;
    - **pipelining** — edge streaming flags, applied on :attr:`graph`
      (Fig. 3: enabled only where it shrinks the makespan);
    - **coflows** — flow groupings with synchronized start, MADD-coupled
      rates and all-or-nothing gating (the §2.2 baseline);
    - **releases** — per-task earliest start times (delaying a flow is
      sometimes the optimal decision, Fig. 2);
    - **placement** — the host assignment applied to logical tasks;
      :attr:`graph` is the *bound* graph, and :attr:`placement` records
      the assignment that produced it;
    - **routes** — per-flow path overrides (members of the fabric's
      candidate sets) replacing the static ECMP pick, threaded into the
      DES via ``Simulator(routes=...)``.

    Default-constructed fields are inert: a Schedule with no placement and
    no routes executes exactly as one predating those decision kinds.
    """
    graph: MXDAG                        # with pipelining flags applied
    policy: str = "fair"
    priorities: dict[str, float] = dataclasses.field(default_factory=dict)
    releases: dict[str, float] = dataclasses.field(default_factory=dict)
    coflows: Optional[list[set[str]]] = None
    placement: dict = dataclasses.field(default_factory=dict)
    routes: dict[str, tuple[str, ...]] = dataclasses.field(
        default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def simulate(self, cluster: Optional[Cluster] = None,
                 routes: Optional[dict] = None,
                 engine: str = "array") -> SimResult:
        """Execute this Schedule in the DES.

        :param cluster: capacities/fabric; default derived from the graph.
        :param routes: extra per-flow route overrides layered on top of
            (and winning over) :attr:`routes`.
        :param engine: ``"array"`` (default), ``"calendar"``, or
            ``"reference"`` — see the engine ladder in the simulator docs.
        :returns: the :class:`~repro.core.simulator.SimResult`.
        """
        merged = {**self.routes, **(routes or {})}
        return simulate(self.graph, cluster, policy=self.policy,
                        priorities=self.priorities, releases=self.releases,
                        coflows=self.coflows, routes=merged or None,
                        engine=engine)


class FairShareScheduler:
    """Baseline: dependency-driven start, fair NIC sharing, no priorities."""

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """An empty decision: default fair sharing on ``graph``.

        :param graph: the MXDAG to run.
        :param cluster: accepted for interface symmetry; unused.
        :returns: a ``policy="fair"`` Schedule with no other decisions.
        """
        return Schedule(graph=graph, policy="fair")


class CoflowConfig:
    """Coflow baseline: caller supplies the grouping (the paper's point in
    §2.2 is precisely that the grouping is ambiguous — Fig. 2(b1..b3));
    :func:`auto_coflows` derives one conventional grouping."""

    def __init__(self, coflows: list[set[str]]):
        """:param coflows: the flow grouping to impose (disjoint sets)."""
        self.coflows = coflows

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Impose the configured grouping under fair sharing.

        :param graph: the MXDAG to run.
        :param cluster: accepted for interface symmetry; unused.
        :returns: a ``policy="fair"`` Schedule carrying the §2.2 coflow
            semantics for the configured groups.
        """
        return Schedule(graph=graph, policy="fair", coflows=self.coflows,
                        meta={"coflows": self.coflows})


def auto_coflows(graph: MXDAG, *, singletons: bool = False,
                 ) -> list[set[str]]:
    """Conventional stage-grouping: flows sharing the same successor set
    (aggregations) or, failing that, the same predecessor set (broadcasts).

    :param graph: the MXDAG whose network tasks are grouped.
    :param singletons: also return one-flow groups.  The default drops
        them (a singleton "coflow" adds nothing to the §2.2 baseline),
        but coflow-*ordering* schedulers (:mod:`repro.core.baselines`)
        need every flow covered — an unordered flow would default to
        priority class 0.0 and preempt the whole ordering.  This switch
        is the one extension the baseline bake-off forced on the coflow
        API.
    :returns: disjoint flow-name groups, in task-insertion order.
    """
    groups: dict[tuple, set[str]] = {}
    for t in graph.network_tasks():
        succ = frozenset(graph.succs(t.name))
        pred = frozenset(graph.preds(t.name))
        key = ("succ", succ) if succ else ("pred", pred)
        groups.setdefault(key, set()).add(t.name)
    return [g for g in groups.values() if singletons or len(g) >= 2]


class PlacementScheduler:
    """Slack-guided greedy placement of logical tasks onto cluster hosts.

    A graph's unbound placement fields form co-location classes (see
    ``MXDAG._location_vars``): a compute task and the endpoints of the
    flows it produces/consumes must land on one host.  Classes are placed
    most-urgent first (ascending analytic slack — "do the hard stuff
    first"), each onto the host minimizing a congestion estimate: for
    every adjacent flow whose other endpoint is already known, the
    bottleneck ratio ``(assigned load + flow size) / capacity`` along the
    candidate route — so oversubscribed uplinks repel placements in
    proportion to how contended they already are — plus a large penalty
    for oversubscribing processor slots.

    With ``des_refine`` (default), the greedy result is then improved by
    what-if DES runs: each class tries its ``max_candidates`` best
    alternative hosts through the scheduler's memoized ``_best`` cache and
    keeps strict makespan improvements.
    """

    def __init__(self, *, max_candidates: int = 4,
                 des_refine: bool = True):
        if max_candidates < 1:
            raise ValueError("max_candidates must be >= 1")
        self.max_candidates = max_candidates
        self.des_refine = des_refine

    # -- helpers -------------------------------------------------------
    @staticmethod
    def _path_of(cluster: Cluster, src: str, dst: str) -> tuple[str, ...]:
        if cluster.topology is not None:
            return cluster.topology.path(src, dst)
        return (nic_out(src), nic_in(dst))

    def place(self, graph: MXDAG, cluster: Cluster, *,
              scheduler: "Optional[MXDAGScheduler]" = None,
              cache: Optional[dict] = None) -> dict:
        """Choose hosts for every undecided co-location class of
        ``graph``; returns an assignment for :meth:`MXDAG.bind`."""
        find, variables = graph._location_vars()
        classes: dict[tuple, list[tuple]] = {}
        for v in variables:
            classes.setdefault(find(v), []).append(v)

        tasks = graph.tasks

        def var_value(v: tuple) -> Optional[str]:
            """The host already bound to location variable ``v``, if any."""
            t = tasks[v[1]]
            if v[0] == "c":
                return t.host
            return t.src if v[0] == "s" else t.dst

        # decisions = classes where *no* member is anchored by a bound
        # field (anchored classes are forced; bind() infers them — their
        # value still counts toward the congestion estimate)
        free: list[tuple] = []
        anchored: dict[tuple, str] = {}
        for root, vs in classes.items():
            vals = {var_value(v) for v in vs} - {None}
            if vals:
                anchored[root] = min(vals)
            else:
                free.append(root)
        if not free:
            return {}

        # urgency: tightest analytic slack of any task in the class
        timing = graph.with_slack()
        slack_of = {root: min(timing[v[1]].slack for v in classes[root])
                    for root in free}
        order = sorted(free, key=lambda r: (slack_of[r], r))

        # congestion state from everything already decided
        load: dict[str, float] = {}
        slot_load: dict[tuple[str, str], int] = {}
        placed: dict[tuple, str] = {}

        def loc(v: tuple) -> Optional[str]:
            """Current (bound or tentatively placed) host of ``v``."""
            val = var_value(v)
            if val is not None:
                return val
            root = find(v)
            host = placed.get(root)
            return host if host is not None else anchored.get(root)

        charged: set[str] = set()

        def charge_ready_flows(names) -> None:
            """Charge flows whose endpoints just became known to links."""
            for n in names:
                if n in charged or tasks[n].kind is not TaskKind.NETWORK:
                    continue
                s, d = loc(("s", n)), loc(("d", n))
                if s is None or d is None:
                    continue
                charged.add(n)
                for l in self._path_of(cluster, s, d):
                    load[l] = load.get(l, 0.0) + tasks[n].size

        for n, t in tasks.items():
            if t.kind is TaskKind.COMPUTE and t.host is not None:
                slot_load[(t.host, t.proc)] = \
                    slot_load.get((t.host, t.proc), 0) + 1
        charge_ready_flows(tasks)

        hosts = list(cluster.hosts)
        ranked: dict[tuple, list[str]] = {}
        for root in order:
            vs = classes[root]
            computes = [n for (k, n) in vs if k == "c"]
            flows = [(n, k) for (k, n) in vs if k != "c"]
            cands = [h for h in hosts
                     if all(cluster.hosts[h].procs.get(tasks[n].proc, 0)
                            >= 1 for n in computes)]
            if not cands:
                raise ValueError(
                    f"no host offers the processor pools needed by "
                    f"{sorted(computes)}")
            scored: list[tuple[float, str]] = []
            for h in cands:
                cost = 0.0
                for n, k in flows:
                    other = loc(("d", n)) if k == "s" else loc(("s", n))
                    if other is None:
                        continue     # charged when the other class lands
                    p = self._path_of(cluster, h, other) if k == "s" \
                        else self._path_of(cluster, other, h)
                    cost += max((load.get(l, 0.0) + tasks[n].size)
                                / cluster.bandwidth(l) for l in p)
                for n in computes:
                    t = tasks[n]
                    spare = cluster.hosts[h].procs.get(t.proc, 0) \
                        - slot_load.get((h, t.proc), 0)
                    if spare < 1:
                        cost += 1e6      # queuing on a busy pool
                scored.append((cost, h))
            scored.sort()
            ranked[root] = [h for _, h in scored]
            placed[root] = ranked[root][0]
            for n in computes:
                t = tasks[n]
                slot_load[(placed[root], t.proc)] = \
                    slot_load.get((placed[root], t.proc), 0) + 1
            charge_ready_flows([n for n, _ in flows])

        # -- what-if DES refinement (memoized via the scheduler cache) --
        if self.des_refine and scheduler is not None:
            best_ms = scheduler._best(
                graph.bind(self._assignment(classes, placed)),
                cluster, cache)[2]
            for root in order:
                for h in ranked[root][:self.max_candidates]:
                    if h == placed[root]:
                        continue
                    trial = dict(placed)
                    trial[root] = h
                    ms = scheduler._best(
                        graph.bind(self._assignment(classes, trial)),
                        cluster, cache)[2]
                    if ms < best_ms - 1e-9:
                        best_ms, placed = ms, trial
        return self._assignment(classes, placed)

    @staticmethod
    def _assignment(classes: dict, placed: dict) -> dict:
        """Express per-class host choices as a bind() assignment (one
        anchor per class is enough — bind() re-derives the same classes
        and propagates it)."""
        out: dict = {}
        flow_ends: dict[str, list] = {}
        for root, host in placed.items():
            vs = classes[root]
            anchor = next((v for v in vs if v[0] == "c"), vs[0])
            if anchor[0] == "c":
                out[anchor[1]] = host
            else:
                ends = flow_ends.setdefault(anchor[1], [None, None])
                ends[0 if anchor[0] == "s" else 1] = host
        for n, (src, dst) in flow_ends.items():
            out[n] = (src, dst)
        return out


class MXDAGScheduler:
    """Principle 1 (§4.1) — critical-path-first co-scheduling.

    1. Analytic forward/backward pass (contention-free) yields per-task
       slack; zero-slack tasks form the critical path.
    2. Flow & compute priorities: critical tasks get class 0; others are
       ordered by ascending slack within class 1 (a non-critical path is
       never allowed to pre-empt the critical path, but among themselves
       tighter paths go first — "without letting the non-critical paths
       have longer completion time than the critical path").
    3. Pipelining: greedily enable a pipelineable edge only if the
       simulated makespan shrinks (Fig. 3 cases 1–3 automated).
    4. Placement: a graph with logical (unbound) tasks is first placed on
       the cluster by the :class:`PlacementScheduler` stage — slack-guided
       greedy host selection that avoids oversubscribed uplinks, refined
       by memoized what-if DES runs — and the resulting assignment is
       recorded on the Schedule.
    5. Routing (``try_routing=True``, needs a fabric topology): each flow
       may be moved off its static ECMP path onto any member of the
       fabric's candidate set when the DES shows a strictly smaller
       makespan; chosen overrides land in ``Schedule.routes``.

    ``memoize`` caches DES results within one :meth:`schedule` call, keyed
    by (graph signature, policy, priorities, routes), so identical what-if
    queries are simulated once — the placement and routing stages share
    the same cache.  ``incremental_pipelining`` replaces the seed's
    fixpoint re-scan of every candidate edge after each accepted decision
    with a worklist that re-evaluates only candidates whose endpoints
    touch resources affected by that decision (a task whose simulated
    start/finish moved, or the accepted edge itself).  Both default on;
    benchmarks flip them off to measure the seed behaviour.

    ``analytic`` picks the substrate for the slack/critical-path passes:
    ``"array"`` runs them as compiled level-batched passes over
    :mod:`repro.core.arrayanalytic`'s interned arrays — *the same
    compile the flat-array DES engine reuses* (``arraysim._compile``
    consumes its name table and adjacency), cached per graph version so
    a schedule() call compiles the graph once for analytics and DES
    together — with ``_priorities`` as an argsort-rank over the slack
    vector; ``"dict"`` is ``MXDAG.with_slack``/``critical_path``
    verbatim (the pre-compiled pipeline, retained as the differential
    oracle and benchmark "before"); ``"auto"`` (default) mirrors the
    DES engine threshold.  The two substrates are bit-equal, so the
    resulting Schedule is identical either way — pinned by the
    ``scale.schedule_*.ref_match`` CI rows and the arrayanalytic golden
    tests.

    On a fully-bound graph with ``try_routing`` off (the defaults), the
    decision pipeline and its outputs are bit-identical to the
    pre-placement scheduler.
    """

    def __init__(self, *, try_pipelining: bool = True,
                 slack_eps: float = 1e-9, memoize: bool = True,
                 incremental_pipelining: bool = True,
                 placement: "Optional[PlacementScheduler]" = None,
                 try_routing: bool = False, engine: str = "auto",
                 analytic: str = "auto",
                 workers: Optional[int] = None):
        self.try_pipelining = try_pipelining
        # workers > 1 lets _best evaluate its independent DES candidates
        # (the initial priority run and the fair floor) in forked worker
        # processes; the chosen Schedule is bit-identical to serial.
        self.workers = workers
        self.slack_eps = slack_eps
        self.memoize = memoize
        self.incremental_pipelining = incremental_pipelining
        self.placement = placement
        self.try_routing = try_routing
        # DES engine for every what-if run this scheduler issues.  The
        # default "auto" picks per graph: the flat-array engine's compile
        # (re-done per pipelining trial, since each trial is a graph
        # copy) and per-run setup only pay off from a few hundred tasks
        # up, while on small graphs the calendar core's constants win —
        # the two are differentially-tested equivalent, so the choice is
        # a pure time optimisation.
        if engine not in ("auto", "array", "calendar", "reference"):
            raise ValueError(f"unknown engine {engine}")
        self.engine = engine
        # analytic substrate for the forward/reverse slack passes and
        # the critical-path walk: "array" runs them as compiled
        # level-batched passes over repro.core.arrayanalytic's interned
        # arrays (bit-equal to the dict implementation — the golden
        # tests assert ==), "dict" is MXDAG.with_slack/critical_path
        # verbatim (the pre-compiled-analytics decision pipeline, kept
        # as the benchmark "before" and differential oracle).  "auto"
        # mirrors the DES engine threshold.
        if analytic not in ("auto", "array", "dict"):
            raise ValueError(f"unknown analytic {analytic}")
        self.analytic = analytic

    def _engine_for(self, g: MXDAG) -> str:
        if self.engine != "auto":
            return self.engine
        return "array" if len(g.tasks) >= 256 else "calendar"

    def _use_array_analytic(self, g: MXDAG) -> bool:
        if self.analytic != "auto":
            return self.analytic == "array"
        return len(g.tasks) >= 256

    def _timing_view(self, g: MXDAG) -> tuple[list, list, list]:
        """(names, slack, latest_completion) per task — the only pieces
        of the forward/reverse analytic pass the decision pipeline
        consumes — from the compiled or the dict substrate (bit-equal
        by the arrayanalytic golden tests; name order may differ, which
        nothing downstream observes)."""
        if self._use_array_analytic(g):
            at = arrayanalytic.analyze(g)
            return at.names, at.slack, at.latest
        timing = g.with_slack()
        names = list(timing)
        return (names, [timing[n].slack for n in names],
                [timing[n].latest_completion for n in names])

    def _priorities(self, graph: MXDAG,
                    timing: Optional[dict] = None) -> dict[str, float]:
        """Principle-1 priority classes from per-task slack.

        ``timing`` may be a ``with_slack()`` dict or ``None`` (computed
        via the configured analytic substrate).  The compiled path is an
        argsort-rank over the slack vector; values are identical to the
        dict path because the rank map is the same sorted-unique-rounded
        table either way.
        """
        if timing is not None:
            names = list(timing)
            slack = [timing[n].slack for n in names]
        else:
            names, slack, _ = self._timing_view(graph)
        return self._priorities_from(names, slack)

    def _priorities_from(self, names: list, slack: list,
                         ) -> dict[str, float]:
        rounded = [round(s, 12) for s in slack]
        ranks = sorted(set(rounded))
        rank = {s: i for i, s in enumerate(ranks)}
        denom = max(len(ranks), 1)
        eps = self.slack_eps
        prio: dict[str, float] = {}
        for n, s, rs in zip(names, slack, rounded):
            if s <= eps:
                prio[n] = CRITICAL
            else:
                # rank-normalized slack keeps classes strictly above CRITICAL
                prio[n] = NONCRITICAL + rank[rs] / denom
        return prio

    @staticmethod
    def _sim_key(sig, policy: str, prio: dict[str, float],
                 routes: Optional[dict]):
        # prio key in dict-insertion order: every producer builds the
        # map in a deterministic per-graph order, so equal content ⇒
        # equal key in practice, and a differently-ordered duplicate
        # only costs a cache miss (re-simulating is always correct) —
        # while skipping the O(n log n) sort per memo lookup
        return (sig, policy, tuple(prio.items()),
                tuple(sorted(routes.items())) if routes else None)

    def _sim(self, g: MXDAG, cluster: Optional[Cluster],
             cache: Optional[dict], policy: str, prio: dict[str, float],
             routes: Optional[dict] = None, sig=None) -> SimResult:
        """One DES run, memoized by (graph signature, policy, priorities,
        route overrides) when a cache is supplied."""
        if cache is None:
            return simulate(g, cluster, policy=policy, priorities=prio,
                            routes=routes or None,
                            engine=self._engine_for(g))
        if sig is None:
            sig_ids = cache.setdefault("sig_ids", {})
            sig = sig_ids.setdefault(g.signature(), len(sig_ids))
        key = self._sim_key(sig, policy, prio, routes)
        res = cache.get(key)
        if res is None:
            res = simulate(g, cluster, policy=policy, priorities=prio,
                           routes=routes or None,
                           engine=self._engine_for(g))
            cache[key] = res
        return res

    def _best(self, g: MXDAG, cluster: Optional[Cluster],
              cache: Optional[dict] = None,
              routes: Optional[dict] = None,
              workers: Optional[int] = None,
              ) -> tuple[str, dict[str, float], float, SimResult]:
        """Principle 1 with its own caveat enforced.

        Strict slack-priority can delay a non-critical path *beyond its
        slack* under contention, which the principle forbids ("without
        letting the non-critical paths have longer completion time than the
        critical path").  So: start from strict priority, iteratively
        promote tasks that the DES shows finishing past their analytic
        latest-completion, and never return anything worse than plain fair
        sharing.  ``cache`` memoizes DES runs across _best calls;
        ``routes`` (per-flow path overrides) apply to every run.

        Compiled-analytic fast path: when every task lands in the
        CRITICAL class (a fully-critical DAG — e.g. any symmetric
        shuffle), the "priority" run is *provably identical* to the
        "fair" run — one priority class means one waterfill group, the
        same (priority, name) dispatch order, and replay never fires —
        so the fair guard reuses the priority result instead of paying
        a second DES run.  The candidate comparison (priority wins
        ties) is unchanged, so the Schedule is bit-identical; the dict
        substrate keeps the pre-PR two-run pipeline verbatim.
        """
        if cache is not None:
            # intern the graph signature: hash the (large) task/edge tuple
            # once per _best call, not once per memo lookup
            sig_ids = cache.setdefault("sig_ids", {})
            sig = sig_ids.setdefault(g.signature(), len(sig_ids))
        else:
            sig = None

        def sim(policy: str, prio: dict[str, float]) -> SimResult:
            """Memoized DES run of ``g`` under (policy, priorities)."""
            return self._sim(g, cluster, cache, policy, prio,
                             routes, sig=sig)

        names, slack, latest = self._timing_view(g)
        prio = self._priorities_from(names, slack)
        cands: list[tuple[str, dict[str, float], float, SimResult]] = []
        cur = dict(prio)
        # Speculative parallel start: the fair-floor run never depends
        # on the promote loop, so with workers>1 the first priority run
        # and the fair run evaluate in concurrent forked processes and
        # land in the memo cache; the loop below then hits the cache for
        # its first iteration and any later promotions stay serial (each
        # depends on the previous run's finish times).  Skipped when the
        # initial classes are all-critical — there the single-class
        # shortcut below makes the fair run free, and forking would
        # *add* a redundant DES.  Results are bit-identical to serial:
        # the same two (policy, priorities) runs feed the same argmin.
        if workers is None:
            workers = self.workers
        fair: Optional[SimResult] = None
        if effective_workers(workers) > 1 and cache is not None and not (
                cur and self._use_array_analytic(g)
                and all(v == CRITICAL for v in cur.values())):
            spec = [("priority", dict(cur)), ("fair", {})]
            out = trial_map(
                lambda i: self._sim(g, cluster, None, spec[i][0],
                                    spec[i][1], routes),
                range(len(spec)), workers, label="_best candidates")
            for (pol, pr), r in zip(spec, out):
                cache.setdefault(self._sim_key(sig, pol, pr, routes), r)
            fair = out[1]
        for _ in range(len(g.tasks)):
            res = sim("priority", cur)
            cands.append(("priority", dict(cur), res.makespan, res))
            finish = res.finish
            cget = cur.get
            late = [n for n, lc in zip(names, latest)
                    if cget(n, 0.0) > CRITICAL
                    and finish[n] > lc + 1e-9]
            if not late:
                break
            for n in late:
                cur[n] = CRITICAL
        if fair is None:
            if cur and self._use_array_analytic(g) \
                    and all(v == CRITICAL for v in cur.values()):
                fair = res               # single class ≡ fair (see above)
            else:
                fair = sim("fair", {})
        cands.append(("fair", {}, fair.makespan, fair))
        return min(cands, key=lambda c: (c[2], c[0] == "fair"))

    def schedule(self, graph: MXDAG,
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Run the full decision pipeline on ``graph``.

        Stages (each only when applicable): placement of logical tasks,
        slack-driven priority classes vs the fair floor, greedy
        pipelining (``try_pipelining``), ECMP rerouting
        (``try_routing``).

        :param graph: the MXDAG to schedule (may contain logical tasks).
        :param cluster: capacities/fabric; default derived from the
            graph (required when placement has choices to make).
        :returns: the winning Schedule with all decision kinds recorded
            (``meta`` carries the critical path and stage diagnostics).
        """
        # the pipeline only mutates the working graph when it flips
        # pipelining flags; without that stage every step is read-only
        # (bind() already copies), so the input graph is used as-is and
        # its version-keyed compiled caches (analytic arrays, DES
        # compile, resource maps) stay warm across repeated schedule()
        # calls — what-if sweeps re-schedule the same graph constantly
        g = graph.copy() if self.try_pipelining else graph
        cache: Optional[dict] = {} if self.memoize else None

        assignment: dict = {}
        if graph.unbound():
            if cluster is None:
                raise ValueError(
                    f"{graph.name} has logical (unbound) tasks; placing "
                    f"them needs an explicit cluster to choose hosts from")
            placer = self.placement or PlacementScheduler()
            assignment = placer.place(graph, cluster,
                                      scheduler=self, cache=cache)
            g = g.bind(assignment)

        if self.try_pipelining:
            # start from no pipelining: paper applies it only when it helps
            for (s, d) in list(g.edges):
                g.set_pipelined(s, d, False)

        policy, prio, best, best_res = self._best(g, cluster, cache)
        decisions: dict[tuple[str, str], bool] = {}

        if self.try_pipelining:
            candidates = sorted(
                ((e.src, e.dst) for e in graph.edges.values()
                 if graph.tasks[e.src].pipelineable
                 and graph.tasks[e.dst].pipelineable),
            )
            if self.incremental_pipelining:
                g, policy, prio, best, best_res = self._greedy_pipeline(
                    g, cluster, cache, candidates, decisions,
                    policy, prio, best, best_res)
            else:
                # seed fixpoint: full candidate re-scan after any accept
                improved = True
                while improved:
                    improved = False
                    for (s, d) in candidates:
                        if decisions.get((s, d)):
                            continue
                        trial = g.copy()
                        trial.set_pipelined(s, d, True)
                        tpolicy, tprio, tms, tres = self._best(
                            trial, cluster, cache)
                        if tms < best - 1e-9:
                            g, best, best_res = trial, tms, tres
                            policy, prio = tpolicy, tprio
                            decisions[(s, d)] = True
                            improved = True

        routes: dict[str, tuple[str, ...]] = {}
        if self.try_routing and cluster is not None \
                and cluster.topology is not None:
            routes, policy, prio, best, best_res = self._route_select(
                g, cluster, cache, policy, prio, best, best_res)

        cp = arrayanalytic.critical_path(g) \
            if self._use_array_analytic(g) else g.critical_path()
        return Schedule(graph=g, policy=policy, priorities=prio,
                        placement=assignment, routes=routes,
                        meta={"pipelined": sorted(k for k, v in
                                                  decisions.items() if v),
                              "critical_path": cp,
                              "predicted_makespan": best})

    def _route_select(self, g: MXDAG, cluster: Cluster,
                      cache: Optional[dict], policy: str,
                      prio: dict[str, float], best: float,
                      best_res: SimResult):
        """Greedy per-flow route selection over the fabric's candidate
        sets (most-urgent flows first).  A flow is moved off its static
        ECMP path only when the DES shows a strictly smaller makespan
        given all overrides accepted so far; a final :meth:`_best` pass
        re-settles priorities under the chosen routes.
        """
        topo = cluster.topology
        routes: dict[str, tuple[str, ...]] = {}
        order = sorted((t.name for t in g.network_tasks()),
                       key=lambda n: (prio.get(n, 0.0), n))
        for n in order:
            t = g.tasks[n]
            cands = topo.paths(t.src, t.dst)
            if len(cands) <= 1:
                continue
            cur = routes.get(n, topo.path(t.src, t.dst))
            chosen = None
            for p in cands:
                if p == cur:
                    continue
                res = self._sim(g, cluster, cache, policy, prio,
                                {**routes, n: p})
                if res.makespan < best - 1e-9:
                    best, chosen, chosen_res = res.makespan, p, res
            if chosen is not None:
                routes[n] = chosen
                best_res = chosen_res
        if routes:
            rpolicy, rprio, rbest, rres = self._best(
                g, cluster, cache, routes=routes)
            if rbest <= best + 1e-12:
                policy, prio, best, best_res = rpolicy, rprio, rbest, rres
        return routes, policy, prio, best, best_res

    def _greedy_pipeline(self, g: MXDAG, cluster: Optional[Cluster],
                         cache: Optional[dict],
                         candidates: list[tuple[str, str]],
                         decisions: dict[tuple[str, str], bool],
                         policy: str, prio: dict[str, float],
                         best: float, best_res: SimResult):
        """Worklist greedy: each candidate edge is evaluated once; an
        accepted decision re-enqueues only the rejected candidates whose
        endpoints touch a resource the decision affected (a task whose
        simulated start/finish moved, or the accepted edge's endpoints).

        This is a heuristic pruning of the seed's full fixpoint re-scan:
        a decision can in principle shift analytic slack (and thus _best
        priorities) for tasks whose simulated timing did not move, so a
        far-away rejected candidate could become profitable without being
        requeued.  Makespan monotonicity is unaffected (only improvements
        are ever accepted); pass ``incremental_pipelining=False`` for the
        seed's exhaustive behaviour.
        """
        res_of = {n: (cluster.resources_for(t) if cluster is not None
                      else t.resources())
                  for n, t in g.tasks.items()}
        queue = list(candidates)
        queued = set(candidates)
        rejected: list[tuple[str, str]] = []
        i = 0
        while i < len(queue):
            s, d = queue[i]
            i += 1
            queued.discard((s, d))
            if decisions.get((s, d)):
                continue
            trial = g.copy()
            trial.set_pipelined(s, d, True)
            tpolicy, tprio, tms, tres = self._best(trial, cluster, cache)
            if tms >= best - 1e-9:
                rejected.append((s, d))
                continue
            affected = set(res_of[s]) | set(res_of[d])
            for n in g.tasks:
                if (abs(best_res.start[n] - tres.start[n]) > 1e-9
                        or abs(best_res.finish[n] - tres.finish[n]) > 1e-9):
                    affected.update(res_of[n])
            g, best, best_res = trial, tms, tres
            policy, prio = tpolicy, tprio
            decisions[(s, d)] = True
            requeue = [c for c in rejected
                       if c not in queued and not decisions.get(c)
                       and (affected & set(res_of[c[0]])
                            or affected & set(res_of[c[1]]))]
            rejected = [c for c in rejected if c not in requeue]
            for c in sorted(requeue):
                queue.append(c)
                queued.add(c)
        return g, policy, prio, best, best_res


class AltruisticMultiScheduler:
    """Principle 2 (§4.2) — altruism across MXDAGs sharing a cluster.

    Each job's critical tasks keep class 0.  A job's non-critical task is
    demoted below *other* jobs' critical tasks only when its slack (from the
    isolated analytic pass) covers the foreign critical work queued on the
    same resource — this implements "delaying its non-critical path resource
    allocation ... without increasing its own end-to-end completion time".

    ``analytic`` picks the substrate, mirroring :class:`MXDAGScheduler`:
    ``"array"`` runs the per-job isolated slack passes as compiled
    level-batched passes over :mod:`repro.core.arrayanalytic` (memoized
    per ``(job name, graph version)`` so a service loop re-admitting the
    same jobs reuses warm passes) and computes each foreign-critical-work
    sum once per ``(resource, excluded job)`` pair instead of once per
    ``(task, resource)`` pair; ``"dict"`` is the original
    ``with_slack`` pipeline verbatim, retained as the bit-exact oracle
    and benchmark "before"; ``"auto"`` (default) picks ``"array"`` from
    256 merged tasks up.  The two substrates produce identical priority
    maps: the per-job slack vectors are bit-equal (arrayanalytic golden
    tests) and the grouped demotion sums add the same floats in the
    same sequential order as the dict path's inner loop.
    """

    def __init__(self, *, try_pipelining: bool = False,
                 analytic: str = "auto"):
        """:param try_pipelining: forwarded to the per-job scheduler.
        :param analytic: ``"auto"`` | ``"array"`` | ``"dict"`` substrate
            for the per-job slack/critical passes and demotion sums.
        """
        self.try_pipelining = try_pipelining
        if analytic not in ("auto", "array", "dict"):
            raise ValueError(f"unknown analytic {analytic}")
        self.analytic = analytic
        # per-job isolated analytics keyed on (job name -> graph
        # version): the same version-keyed trick as MXDAGScheduler._best,
        # so repeated service-loop calls reuse warm passes.
        self._job_cache: dict[str, tuple] = {}
        # merged-graph (+ resource maps) keyed on the job set identity
        self._merged_cache: dict[tuple, tuple] = {}
        # per-job resource-map fragments (job name -> ((version, cluster
        # signature), resource map, task->resources)) the merged view
        # concatenates — jobs rarely change between service-loop calls
        self._res_cache: dict[str, tuple] = {}

    def _use_array(self, graphs: list[MXDAG]) -> bool:
        if self.analytic != "auto":
            return self.analytic == "array"
        return sum(len(g.tasks) for g in graphs) >= 256

    @staticmethod
    def _merge(graphs: list[MXDAG]) -> MXDAG:
        """Union the jobs into one graph, rejecting name collisions."""
        merged = MXDAG("+".join(g.name for g in graphs))
        owner: dict[str, str] = {}
        for g in graphs:
            for t in g:
                who = f"{g.name!r} (job {t.job!r})"
                if t.name in owner:
                    raise ValueError(
                        f"cross-job task name collision: {t.name!r} is "
                        f"defined by both {owner[t.name]} and {who}; "
                        f"task names must be unique across the jobs "
                        f"sharing a cluster (prefix them with the job "
                        f"name, as builders.mapreduce does)")
                owner[t.name] = who
                merged.add(t)
            for e in g.edges.values():
                merged.add_edge(e.src, e.dst, pipelined=e.pipelined)
        return merged

    def schedule(self, graphs: list[MXDAG],
                 cluster: Optional[Cluster] = None) -> Schedule:
        """Schedule several jobs altruistically on one cluster.

        :param graphs: the jobs; task names must be globally unique.
        :param cluster: shared capacities; default derived from the
            merged graph.
        :returns: one Schedule over the merged graph whose priority
            classes interleave the jobs per Principle 2.
        """
        if self._use_array(graphs):
            return self._schedule_array(graphs, cluster)
        return self._schedule_dict(graphs, cluster)

    def _schedule_dict(self, graphs: list[MXDAG],
                       cluster: Optional[Cluster] = None) -> Schedule:
        """The original dict pipeline, verbatim — the differential
        oracle for the compiled path and the benchmark "before"."""
        merged = self._merge(graphs)

        # isolated analytics per job
        prio: dict[str, float] = {}
        slack: dict[str, float] = {}
        critical: dict[str, set[str]] = {}
        for g in graphs:
            timing = g.with_slack()
            crit = {n for n, tm in timing.items() if tm.slack <= 1e-9}
            critical[g.name] = crit
            for n, tm in timing.items():
                slack[n] = tm.slack
                prio[n] = CRITICAL if n in crit else NONCRITICAL

        # altruistic demotion, bounded by slack; fabric-aware when the
        # cluster has a Topology (contention on shared uplinks counts too)
        by_resource = merged.resource_map(cluster)
        res_of = {n: (cluster.resources_for(t) if cluster is not None
                      else t.resources())
                  for n, t in merged.tasks.items()}
        for g in graphs:
            others_crit = set().union(*(critical[o.name] for o in graphs
                                        if o.name != g.name)) \
                if len(graphs) > 1 else set()
            for n in g.tasks:
                if prio[n] != NONCRITICAL:
                    continue
                foreign = 0.0
                for r in res_of[n]:
                    foreign += sum(merged.tasks[m].size
                                   for m in by_resource[r]
                                   if m in others_crit)
                if foreign > 0 and slack[n] >= foreign - 1e-9:
                    prio[n] = ALTRUIST_DEMOTED
        return Schedule(graph=merged, policy="priority", priorities=prio,
                        meta={"critical": critical})

    def _job_analytics(self, g: MXDAG) -> tuple[dict[str, float],
                                                set[str]]:
        """Memoized per-job isolated (slack map, critical set) from the
        compiled analytic pass, keyed on the job's graph version."""
        cached = self._job_cache.get(g.name)
        if cached is not None and cached[0] == g._version:
            return cached[1], cached[2]
        at = arrayanalytic.analyze(g)
        slack = dict(zip(at.names, at.slack))
        crit = {n for n, s in slack.items() if s <= 1e-9}
        self._job_cache[g.name] = (g._version, slack, crit)
        return slack, crit

    def _merged_view(self, graphs: list[MXDAG],
                     cluster: Optional[Cluster]) -> tuple:
        """Memoized (merged graph, resource→tasks map, task→resources
        map) keyed on the job-set identity and the cluster."""
        sig = cluster.signature() if cluster is not None else None
        key = (tuple((g.name, g._version) for g in graphs), sig)
        cached = self._merged_cache.get(key)
        if cached is not None:
            return cached
        # bulk union (no per-edge cycle walk — see MXDAG.union) plus
        # per-job memoized resource maps concatenated in job order:
        # merged.resource_map iterates tasks in insertion order, which
        # is exactly job order then within-job insertion order, so the
        # concatenation reproduces its lists element for element (the
        # demotion sums below depend on that order for bit-exactness
        # against the dict oracle).
        merged = MXDAG.union(graphs)
        by_resource: dict[str, list[str]] = {}
        res_of: dict[str, tuple] = {}
        for g in graphs:
            rmap, jres = self._job_resources(g, cluster, sig)
            for r, ns in rmap.items():
                lst = by_resource.get(r)
                if lst is None:
                    by_resource[r] = list(ns)
                else:
                    lst.extend(ns)
            res_of.update(jres)
        if len(self._merged_cache) >= 64:     # service loops churn keys
            self._merged_cache.clear()
        self._merged_cache[key] = (merged, by_resource, res_of)
        return merged, by_resource, res_of

    def _job_resources(self, g: MXDAG, cluster: Optional[Cluster],
                       sig) -> tuple:
        """Memoized per-job (resource map, task→resources) fragments,
        keyed on the job's graph version and the cluster signature."""
        cached = self._res_cache.get(g.name)
        if cached is not None and cached[0] == (g._version, sig):
            return cached[1], cached[2]
        rmap = g.resource_map(cluster)
        jres = {n: (cluster.resources_for(t) if cluster is not None
                    else t.resources())
                for n, t in g.tasks.items()}
        self._res_cache[g.name] = ((g._version, sig), rmap, jres)
        return rmap, jres

    def _schedule_array(self, graphs: list[MXDAG],
                        cluster: Optional[Cluster] = None) -> Schedule:
        """The compiled pipeline: per-job passes over the interned
        arrays, demotion sums grouped per (resource, excluded job).

        Bit-exact vs :meth:`_schedule_dict`: each grouped sum walks the
        same ``by_resource[r]`` slice in the same order the dict path's
        inner ``sum()`` does — filtering on "critical and foreign" picks
        the identical float subsequence, so Python's strictly sequential
        ``sum`` yields the identical value; it is merely computed once
        per (resource, job) instead of once per (task, resource).
        """
        merged, by_resource, res_of = self._merged_view(graphs, cluster)

        prio: dict[str, float] = {}
        slack: dict[str, float] = {}
        critical: dict[str, set[str]] = {}
        for g in graphs:
            jslack, crit = self._job_analytics(g)
            critical[g.name] = crit
            for n, s in jslack.items():
                slack[n] = s
                prio[n] = CRITICAL if n in crit else NONCRITICAL

        # crit sets are disjoint (names are globally unique), so
        # "critical for some OTHER job" ≡ "critical and not mine"
        all_crit = set()
        for c in critical.values():
            all_crit |= c
        tasks = merged.tasks
        foreign_of: dict[tuple[str, str], float] = {}
        for g in graphs:
            if len(graphs) > 1:
                own = critical[g.name]
                others_crit = {m for m in all_crit if m not in own}
            else:
                others_crit = set()
            jname = g.name
            for n in g.tasks:
                if prio[n] != NONCRITICAL:
                    continue
                foreign = 0.0
                for r in res_of[n]:
                    fr = foreign_of.get((r, jname))
                    if fr is None:
                        fr = sum(tasks[m].size for m in by_resource[r]
                                 if m in others_crit)
                        foreign_of[(r, jname)] = fr
                    foreign += fr
                if foreign > 0 and slack[n] >= foreign - 1e-9:
                    prio[n] = ALTRUIST_DEMOTED
        return Schedule(graph=merged, policy="priority", priorities=prio,
                        meta={"critical": critical})
