"""Unit tests: discrete-event simulator semantics."""
import pytest

from repro.core import Cluster, Host, MXDAG, compute, flow, simulate
from repro.core import builders


def two_flow_graph(s1=1.0, s2=1.0):
    """Two flows leaving the same host A, no dependencies."""
    g = MXDAG()
    g.add(flow("f1", s1, "A", "B"))
    g.add(flow("f2", s2, "A", "C"))
    return g


class TestBasics:
    def test_single_compute(self):
        g = MXDAG()
        g.add(compute("a", 2.0, "A"))
        r = simulate(g)
        assert r.finish["a"] == pytest.approx(2.0)

    def test_chain(self):
        g = MXDAG()
        g.chain(compute("a", 1.0, "A"), flow("f", 2.0, "A", "B"),
                compute("b", 1.0, "B"))
        r = simulate(g)
        assert r.makespan == pytest.approx(4.0)

    def test_zero_size_task(self):
        g = MXDAG()
        g.chain(compute("a", 0.0, "A"), compute("b", 1.0, "A"))
        assert simulate(g).makespan == pytest.approx(1.0)

    def test_release_time(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        r = simulate(g, releases={"a": 3.0})
        assert r.start["a"] == pytest.approx(3.0)
        assert r.makespan == pytest.approx(4.0)


class TestNICSharing:
    def test_fair_share_halves_rate(self):
        r = simulate(two_flow_graph())
        assert r.finish["f1"] == pytest.approx(2.0)
        assert r.finish["f2"] == pytest.approx(2.0)

    def test_priority_serializes(self):
        r = simulate(two_flow_graph(), policy="priority",
                     priorities={"f1": 0, "f2": 1})
        assert r.finish["f1"] == pytest.approx(1.0)
        assert r.finish["f2"] == pytest.approx(2.0)

    def test_priority_is_preemptive_for_flows(self):
        # f2 starts alone, f1 (higher prio) arrives later and takes the NIC
        g = MXDAG()
        g.add(flow("f2", 2.0, "A", "C"))
        g.add(compute("gate", 1.0, "A"))
        g.add(flow("f1", 1.0, "A", "B"))
        g.add_edge("gate", "f1")
        r = simulate(g, policy="priority", priorities={"f1": 0, "f2": 1})
        assert r.finish["f1"] == pytest.approx(2.0)
        assert r.finish["f2"] == pytest.approx(3.0)   # preempted 1s

    def test_heterogeneous_nic(self):
        g = MXDAG()
        g.add(flow("f", 1.0, "A", "B"))
        cl = Cluster([Host("A", nic_out=0.5), Host("B")])
        assert simulate(g, cl).makespan == pytest.approx(2.0)

    def test_different_nics_dont_contend(self):
        g = MXDAG()
        g.add(flow("f1", 1.0, "A", "B"))
        g.add(flow("f2", 1.0, "C", "D"))
        r = simulate(g)
        assert r.makespan == pytest.approx(1.0)

    def test_ingress_contention(self):
        g = MXDAG()
        g.add(flow("f1", 1.0, "A", "C"))
        g.add(flow("f2", 1.0, "B", "C"))
        r = simulate(g)
        assert r.makespan == pytest.approx(2.0)


class TestComputeSlots:
    def test_exclusive_slot_serializes(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "H"))
        g.add(compute("b", 1.0, "H"))
        r = simulate(g)
        assert r.makespan == pytest.approx(2.0)

    def test_two_slots_parallel(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "H"))
        g.add(compute("b", 1.0, "H"))
        cl = Cluster([Host("H", procs={"cpu": 2})])
        assert simulate(g, cl).makespan == pytest.approx(1.0)

    def test_dispatch_by_priority(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "H"))
        g.add(compute("b", 1.0, "H"))
        r = simulate(g, policy="priority", priorities={"b": 0, "a": 1})
        assert r.start["b"] == pytest.approx(0.0)
        assert r.start["a"] == pytest.approx(1.0)

    def test_nonpreemptive_compute(self):
        # low-prio a starts first (alone), high-prio b arrives later but
        # must wait: compute is non-preemptive
        g = MXDAG()
        g.add(compute("a", 2.0, "H"))
        g.add(compute("gate", 1.0, "G"))
        g.add(compute("b", 1.0, "H"))
        g.add_edge("gate", "b")
        r = simulate(g, policy="priority", priorities={"b": 0, "a": 1})
        assert r.start["b"] == pytest.approx(2.0)


class TestPipelining:
    def test_pipelined_chain_matches_eq2(self):
        from repro.core.graph import MXDAG as G
        a = compute("a", 4.0, "A", unit=1.0)
        f = flow("f", 8.0, "A", "B", unit=2.0)
        g = MXDAG()
        g.chain(a, f, pipelined=True)
        r = simulate(g)
        assert r.makespan == pytest.approx(G.len_pipelined([a, f]))

    def test_unpipelined_chain_matches_eq1(self):
        a = compute("a", 4.0, "A", unit=1.0)
        f = flow("f", 8.0, "A", "B", unit=2.0)
        g = MXDAG()
        g.chain(a, f, pipelined=False)
        assert simulate(g).makespan == pytest.approx(12.0)

    def test_consumer_gated_by_producer_units(self):
        # producer slower than consumer: consumer starves between units
        a = compute("a", 4.0, "A", unit=1.0)
        b = compute("b", 2.0, "B", unit=0.5)
        g = MXDAG()
        g.chain(a, b, pipelined=True)
        r = simulate(g)
        # b's last quarter needs a fully delivered: finish = 4 + 0.5
        assert r.makespan == pytest.approx(4.5)

    def test_pipelined_flow_occupies_nic_eagerly(self):
        # paper §4.1: streaming flows contend in the top class
        g = MXDAG()
        a = compute("a", 1.0, "A", unit=0.25)
        g.add(a)
        g.add(flow("fcrit", 1.0, "A", "B"))
        g.add(flow("fpipe", 1.0, "A", "C", unit=0.25))
        g.add_edge("a", "fpipe", pipelined=True)
        r = simulate(g, policy="priority",
                     priorities={"fcrit": 0, "fpipe": 5})
        # fpipe streams from t=0.25 sharing with fcrit despite low priority
        assert r.finish["fcrit"] > 1.0 + 1e-6


class TestCoflow:
    def test_synchronized_start_and_fair_coupling(self):
        # f2 ready at t=0, f1 gated by a 1s compute; coflow syncs both to t=1
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        g.add(flow("f1", 1.0, "A", "B"))
        g.add(flow("f2", 1.0, "A", "C"))
        g.add_edge("a", "f1")
        r = simulate(g, coflows=[{"f1", "f2"}])
        assert r.start["f2"] == pytest.approx(1.0)
        # share A egress: both finish at 3 (MADD: equal sizes, equal rates)
        assert r.finish["f1"] == pytest.approx(3.0)
        assert r.finish["f2"] == pytest.approx(3.0)

    def test_madd_finish_together_unequal_sizes(self):
        g = MXDAG()
        g.add(flow("f1", 1.0, "A", "B"))
        g.add(flow("f2", 3.0, "A", "C"))
        r = simulate(g, coflows=[{"f1", "f2"}])
        assert r.finish["f1"] == pytest.approx(r.finish["f2"], rel=1e-6)
        assert r.finish["f2"] == pytest.approx(4.0)

    def test_all_or_nothing_gates_successor(self):
        g = MXDAG()
        g.add(flow("f1", 1.0, "A", "B"))
        g.add(flow("f2", 3.0, "A", "C"))
        g.add(compute("b", 1.0, "B"))
        g.add_edge("f1", "b")
        r = simulate(g, coflows=[{"f1", "f2"}])
        # b waits for the whole coflow (4.0), not just f1
        assert r.start["b"] == pytest.approx(4.0)

    def test_coflow_member_must_be_flow(self):
        g = MXDAG()
        g.add(compute("a", 1.0, "A"))
        with pytest.raises(ValueError):
            simulate(g, coflows=[{"a"}])


class TestInvariants:
    def test_des_never_beats_contention_free_bound(self):
        for builder in (builders.fig1_jobs, builders.fig2a, builders.fig2b,
                        builders.fig3, lambda: builders.ddl(3)):
            g = builder()
            assert simulate(g).makespan >= g.makespan() - 1e-9

    def test_job_completion_tracked(self):
        j1, j2 = builders.mapreduce_pair()
        m = MXDAG("m")
        for t in list(j1) + list(j2):
            m.add(t)
        for e in list(j1.edges.values()) + list(j2.edges.values()):
            m.add_edge(e.src, e.dst)
        r = simulate(m)
        assert set(r.job_completion) == {"job1", "job2"}
        assert r.makespan == pytest.approx(max(r.job_completion.values()))
