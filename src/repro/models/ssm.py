"""Mamba2 / SSD (state-space duality) blocks: chunked train path +
single-step decode recurrence.

The chunked SSD algorithm (arXiv:2405.21060 §6) splits the sequence into
chunks of length Q: a quadratic attention-like intra-chunk term plus a
linear inter-chunk state recurrence (scanned).  This is the TPU-friendly
form — the intra-chunk einsums are MXU matmuls; ``repro.kernels.ssd``
provides the Pallas kernel for the intra-chunk term.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rmsnorm

Params = dict


def ssm_dims(cfg: ArchConfig) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return {"d_inner": d_in, "n_heads": nh, "head_dim": cfg.ssm_head_dim,
            "n_groups": cfg.ssm_n_groups, "d_state": cfg.ssm_state,
            "conv_dim": d_in + 2 * cfg.ssm_n_groups * cfg.ssm_state}


def ssm_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    dims = ssm_dims(cfg)
    d, d_in, nh = cfg.d_model, dims["d_inner"], dims["n_heads"]
    G, N, W = dims["n_groups"], dims["d_state"], cfg.ssm_conv
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * G * N + nh     # z, xBC, dt
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (W, dims["conv_dim"]),
                                     jnp.float32) / math.sqrt(W)
                   ).astype(dtype),
        "conv_b": jnp.zeros((dims["conv_dim"],), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[3], d_in, d, dtype=dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < l <= i} x[..., l]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: [B,L,H,P], dt: [B,L,H] (post-softplus), A: [H] (negative),
    Bm,Cm: [B,L,G,N] with H % G == 0.  Returns (y [B,L,H,P],
    final_state [B,H,P,N]).
    """
    Bsz, L, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    f32 = jnp.float32
    xc = xh.reshape(Bsz, nc, chunk, H, Pd).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N).astype(f32)

    dA = dtc * A                                            # [B,nc,Q,H]
    cum = jnp.cumsum(dA, axis=2)                            # [B,nc,Q,H]

    # ---- intra-chunk (quadratic, attention-like) ----------------------
    Lmat = jnp.exp(_segsum(jnp.swapaxes(dA, 2, 3)))         # [B,nc,H,Q,Q]
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)           # [B,nc,G,Q,Q]
    CB = jnp.repeat(CB, hpg, axis=2)                        # [B,nc,H,Q,Q]
    xdt = xc * dtc[..., None]                               # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", CB * Lmat, xdt)

    # ---- chunk states --------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # [B,nc,Q,H]
    # broadcast groups -> heads
    Bh = jnp.repeat(Bc[:, :, :, :, None, :], hpg, axis=4
                    ).reshape(Bsz, nc, chunk, H, N)
    Ch = jnp.repeat(Cc[:, :, :, :, None, :], hpg, axis=4
                    ).reshape(Bsz, nc, chunk, H, N)
    states = jnp.einsum("bcqhn,bcqhp->bchpn",
                        Bh * decay_to_end[..., None],
                        xdt)                                # [B,nc,H,P,N]

    # ---- inter-chunk recurrence (scan over chunks) ---------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # [B,nc,H]
    s0 = (jnp.zeros((Bsz, H, Pd, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(s, inp):
        dec, st = inp                                       # [B,H], [B,H,P,N]
        s_new = s * dec[..., None, None] + st
        return s_new, s

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # [B,nc,H,P,N]

    decay_from_start = jnp.exp(cum)                         # [B,nc,Q,H]
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Ch * decay_from_start[..., None], prev_states)

    y = (y_intra + y_inter).reshape(Bsz, L, H, Pd)
    return y.astype(xh.dtype), final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  x: [B,L,C]; w: [W,C].  Returns (y, new
    state [B,W-1,C]) — state carries the last W-1 inputs for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B,L+W-1,C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):, :]
    return jax.nn.silu(y + b), new_state


def ssm_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
              cache: Optional[Params] = None,
              chunk: Optional[int] = None):
    """Mamba2 block.  Train/prefill: cache None, x [B,L,d].
    Decode: x [B,1,d], cache {"conv": [B,W-1,C], "state": [B,H,P,N]}.
    Returns (y [B,L,d], new_cache)."""
    dims = ssm_dims(cfg)
    B_, L, d = x.shape
    d_in, nh, hd = dims["d_inner"], dims["n_heads"], dims["head_dim"]
    G, N = dims["n_groups"], dims["d_state"]

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + dims["conv_dim"]]
    dt_raw = zxbcdt[..., -nh:]

    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)

    xs = xBC[..., :d_in].reshape(B_, L, nh, hd)
    Bm = xBC[..., d_in:d_in + G * N].reshape(B_, L, G, N)
    Cm = xBC[..., d_in + G * N:].reshape(B_, L, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                # [H], negative

    if cache is None:
        y, final = ssd_chunked(xs, dt, A, Bm, Cm,
                               min(chunk or cfg.ssm_chunk, L))
        new_cache = None
    else:
        # single-step recurrence: S = exp(dt*A) S + dt * B ⊗ x ; y = C·S
        s = cache["state"].astype(jnp.float32)              # [B,H,P,N]
        hpg = nh // G
        Bh = jnp.repeat(Bm[:, 0, :, None, :], hpg, axis=2
                        ).reshape(B_, nh, N).astype(jnp.float32)
        Ch = jnp.repeat(Cm[:, 0, :, None, :], hpg, axis=2
                        ).reshape(B_, nh, N).astype(jnp.float32)
        dt0 = dt[:, 0]                                      # [B,H]
        xe = xs[:, 0].astype(jnp.float32)                   # [B,H,P]
        dec = jnp.exp(dt0 * A)                              # [B,H]
        s = s * dec[..., None, None] \
            + jnp.einsum("bhn,bhp,bh->bhpn", Bh, xe, dt0)
        y = jnp.einsum("bhn,bhpn->bhp", Ch, s)[:, None]     # [B,1,H,P]
        y = y.astype(x.dtype)
        final = s
        new_cache = {"conv": new_conv, "state": final}

    y = y + (p["D"].astype(jnp.float32)[:, None]
             * xs.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, L, d_in)
    y = rmsnorm(p["norm_w"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]
    if cache is None:
        return out, None
    return out, new_cache


def ssm_cache_init(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> Params:
    dims = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, dims["conv_dim"]),
                          dtype),
        "state": jnp.zeros((batch, dims["n_heads"], dims["head_dim"],
                            dims["d_state"]), jnp.float32),
    }
