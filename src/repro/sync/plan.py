"""SyncPlan: MXDAG-driven gradient-sync planning (the paper → the mesh).

``step_mxdag`` builds the Fig. 6 MXDAG for one training step of an
assigned arch at production scale: BP/FP compute MXTasks per layer (sized
from the roofline constants) and push/pull network MXTasks for each
layer's gradient reduce-scatter + param all-gather (sized from grad bytes
over ICI bandwidth).  ``plan_sync`` then schedules it with the Principle-1
scheduler and compares against the barrier (coflow-like all-at-the-end)
schedule — choosing ``bucketed`` (per-layer collectives inside the
backward loop, overlappable) only when the MXDAG analysis predicts a win,
exactly the paper's "pipelines applied only when they shrink execution
time".  The realized JAX mechanism is repro/sync/overlap.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ArchConfig, RunConfig, ShapeConfig
from repro.core import MXDAGScheduler, simulate
from repro.core.builders import ddl
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS


@dataclasses.dataclass
class SyncPlan:
    mode: str                      # "bucketed" | "barrier"
    order: list[str]               # push priority order (layer names)
    predicted_bucketed: float      # MXDAG-scheduled makespan (s)
    predicted_barrier: float       # single-barrier makespan (s)
    mxdag_size: int

    @property
    def predicted_speedup(self) -> float:
        return self.predicted_barrier / max(self.predicted_bucketed, 1e-12)


def _per_layer_times(cfg: ArchConfig, shape: ShapeConfig, chips: int,
                     tp: int) -> tuple[float, float, float]:
    """(fp_s, bp_s, sync_s) per layer per step at the assigned scale."""
    n_layer = cfg.param_counts()["active"] / max(cfg.n_layers, 1)
    tokens = shape.global_batch * shape.seq_len
    dp = max(chips // tp, 1)
    fp = 2.0 * n_layer * tokens / (chips * PEAK_FLOPS)
    bp = 2.0 * fp
    # grad RS + param AG: 2 × layer grad bytes (bf16) across dp over ICI
    layer_bytes = (cfg.param_counts()["total"] / max(cfg.n_layers, 1)) \
        * 2.0 / tp
    sync = 2.0 * layer_bytes * (dp - 1) / dp / ICI_BW
    return fp, bp, sync


def step_mxdag(cfg: ArchConfig, shape: ShapeConfig, *, chips: int = 256,
               tp: int = 16, n_layers: Optional[int] = None,
               unit_frac: Optional[float] = None):
    """Fig. 6 MXDAG for one step (push=grad RS, pull=param AG).
    ``unit_frac`` makes tasks pipelineable (chunked collectives)."""
    L = n_layers or cfg.n_layers
    fp, bp, sync = _per_layer_times(cfg, shape, chips, tp)
    return ddl(L, bp=bp, fp=fp, push=sync / 2, pull=sync / 2,
               unit_frac=unit_frac)


def plan_sync(cfg: ArchConfig, shape: ShapeConfig, *, chips: int = 256,
              tp: int = 16, run: Optional[RunConfig] = None) -> SyncPlan:
    L = cfg.n_layers
    g = step_mxdag(cfg, shape, chips=chips, tp=tp)
    sched = MXDAGScheduler(try_pipelining=False).schedule(g)
    bucketed = sched.simulate().makespan

    # barrier baseline: all pushes/pulls grouped as one coflow each —
    # gradient sync happens strictly after the full backward
    fp, bp, sync = _per_layer_times(cfg, shape, chips, tp)
    gb = ddl(1, bp=bp * L, fp=fp * L, push=sync * L / 2, pull=sync * L / 2)
    barrier = simulate(gb).makespan

    prio = {k: v for k, v in sched.priorities.items()
            if k.startswith("push")}
    order = sorted(prio, key=lambda k: prio[k])
    mode = "bucketed" if bucketed < barrier - 1e-12 else "barrier"
    return SyncPlan(mode=mode, order=order,
                    predicted_bucketed=bucketed,
                    predicted_barrier=barrier,
                    mxdag_size=len(g))
