"""Per-architecture smoke tests + decode-vs-prefill consistency.

Every assigned arch instantiates a REDUCED config of the same family and
runs one forward/train step on CPU asserting output shapes + no NaNs
(framework requirement).  Consistency tests verify that token-by-token
decoding with a KV/SSM cache reproduces the full-sequence forward logits —
this covers the GQA cache, the MLA *absorbed* decode path, partial-RoPE,
and the SSD single-step recurrence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import RunConfig
from repro.models import Model, derive_segments

ALL_ARCHS = sorted(configs.ARCHS)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def make_batch(cfg, rng, B=2, S=16):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder_layers:
        batch["audio_embeds"] = 0.01 * jnp.ones(
            (B, cfg.max_source_positions, cfg.d_model), jnp.bfloat16)
    if cfg.vision_embed_dim:
        batch["vision_embeds"] = 0.01 * jnp.ones(
            (B, cfg.vision_seq, cfg.vision_embed_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, mesh):
    """Reduced config: one loss+grad evaluation, finite, right shapes."""
    cfg = configs.get_smoke(arch)
    m = Model(cfg, RunConfig(remat=True), mesh=mesh)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    batch = make_batch(cfg, rng)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: m.loss(p, b)[0]))(params, batch)
    assert jnp.isfinite(loss), arch
    flat, _ = jax.tree.flatten(grads)
    assert all(jnp.all(jnp.isfinite(g.astype(jnp.float32))) for g in flat)
    # shapes of grads match params
    assert jax.tree.map(jnp.shape, grads) == jax.tree.map(jnp.shape, params)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch, mesh):
    cfg = configs.get_smoke(arch)
    m = Model(cfg, RunConfig(remat=False), mesh=mesh)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    B, S = 2, 16
    batch = make_batch(cfg, rng, B, S)
    logits = jax.jit(m.forward)(params, batch)
    n_prefix = cfg.vision_seq if cfg.vision_embed_dim else 0
    assert logits.shape == (B, S + n_prefix, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch, mesh):
    cfg = configs.get_smoke(arch)
    m = Model(cfg, RunConfig(remat=False), mesh=mesh)
    rng = jax.random.PRNGKey(2)
    params = m.init(rng)
    B = 2
    cache = m.init_cache(B, 32)
    tok = jax.random.randint(rng, (B, 1), 0, cfg.vocab_size)
    enc = (0.01 * jnp.ones((B, cfg.max_source_positions, cfg.d_model),
                           jnp.bfloat16) if cfg.encoder_layers else None)
    step = jax.jit(lambda p, c, t, i: m.decode_step(p, c, t, i, enc_out=enc))
    logits, cache2 = step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


# ----------------------------------------------------------------------
# decode == prefill consistency (fp32 for tight comparison)
# ----------------------------------------------------------------------
CONSISTENCY_ARCHS = ["deepseek-7b", "deepseek-v3-671b", "chatglm3-6b",
                     "mamba2-130m", "jamba-v0.1-52b"]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_prefill(arch, mesh):
    cfg = configs.get_smoke(arch)
    m = Model(cfg, RunConfig(remat=False), mesh=mesh, dtype=jnp.float32)
    rng = jax.random.PRNGKey(3)
    params = m.init(rng)
    B, S = 2, 8
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    full = jax.jit(m.forward)(params, {"tokens": tokens})

    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1],
                             jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


# ----------------------------------------------------------------------
# structural tests
# ----------------------------------------------------------------------
class TestSegments:
    def test_jamba_pattern(self):
        cfg = configs.get("jamba-v0.1-52b")
        segs = derive_segments(cfg)
        total = sum(len(s.pattern) * s.repeats for s in segs)
        assert total == 32
        # single period-8 segment scanned 4x (compile-size invariant)
        assert len(segs) == 1 and segs[0].repeats == 4
        mixers = [b.mixer for b in segs[0].pattern]
        assert mixers.count("attn") == 1 and mixers.count("mamba") == 7
        # MoE every other layer
        ffns = [b.ffn for s in segs for b in s.pattern for _ in [0]]
        assert ffns.count("moe") == 4

    def test_deepseek_v3_regions(self):
        cfg = configs.get("deepseek-v3-671b")
        segs = derive_segments(cfg)
        assert segs[0].repeats * len(segs[0].pattern) == 3
        assert all(b.ffn == "dense" for b in segs[0].pattern)
        assert segs[1].repeats * len(segs[1].pattern) == 58
        assert all(b.ffn == "moe" for b in segs[1].pattern)

    def test_mamba2_no_mlp(self):
        cfg = configs.get("mamba2-130m")
        segs = derive_segments(cfg)
        assert all(b.mixer == "mamba" and b.ffn == "none"
                   for s in segs for b in s.pattern)

    def test_total_layers(self):
        for name, cfg in configs.ARCHS.items():
            segs = derive_segments(cfg)
            total = sum(len(s.pattern) * s.repeats for s in segs)
            assert total == cfg.n_layers, name


class TestParamCounts:
    """param_counts drives MODEL_FLOPS = 6·N·D in the roofline analysis."""

    def test_deepseek_7b_about_7b(self):
        n = configs.get("deepseek-7b").param_counts()["total"]
        assert 6e9 < n < 8e9, n

    def test_deepseek_v3_total_and_active(self):
        pc = configs.get("deepseek-v3-671b").param_counts()
        assert 5.5e11 < pc["total"] < 7.5e11, pc
        assert 3.0e10 < pc["active"] < 4.5e10, pc

    def test_olmoe_total_and_active(self):
        pc = configs.get("olmoe-1b-7b").param_counts()
        assert 5e9 < pc["total"] < 8e9, pc
        assert 0.8e9 < pc["active"] < 1.7e9, pc

    def test_mamba2_about_130m(self):
        n = configs.get("mamba2-130m").param_counts()["total"]
        assert 0.9e8 < n < 1.8e8, n

    def test_dense_active_equals_total(self):
        for name in ("deepseek-7b", "nemotron-4-15b", "chatglm3-6b",
                     "deepseek-coder-33b"):
            pc = configs.get(name).param_counts()
            assert pc["total"] == pc["active"], name
