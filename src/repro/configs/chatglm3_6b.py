"""chatglm3-6b — dense, 2-group GQA, 2d (half-dim) RoPE.

[arXiv:2406.12793; hf]  28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024; rotary applied to half the head dims (rotary_fraction=0.5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rotary_fraction=0.5,
    rope_theta=1e4,
)
