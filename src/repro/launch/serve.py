"""Serving step assembly: prefill + batched greedy decode.

``make_serve_step`` returns the single-token decode function the
decode/long-context dry-run cells lower; ``main`` runs a small real
serving demo (batched requests, continuous decode) on CPU.
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import RunConfig
from repro.launch.mesh import dp_axes
from repro.models import Model


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens, index):
        logits, cache = model.decode_step(params, cache, tokens, index)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return next_tok.astype(jnp.int32), cache
    return serve_step


def main(argv: Optional[list[str]] = None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="mamba2-130m")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=32)
    args = p.parse_args(argv)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_smoke(args.arch)
    model = Model(cfg, RunConfig(remat=False), mesh=mesh,
                  dp_axes=dp_axes(mesh))
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    B = args.batch
    max_len = args.prompt_len + args.gen
    cache = model.init_cache(B, max_len)
    prompts = jax.random.randint(rng, (B, args.prompt_len), 0,
                                 cfg.vocab_size)

    step = jax.jit(make_serve_step(model))
    # prefill token-by-token (simple; a fused prefill is the prefill cell)
    tok = prompts[:, :1]
    for t in range(args.prompt_len):
        tok, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    generated = [tok]
    for t in range(args.prompt_len, max_len - 1):
        tok, cache = step(params, cache, tok, jnp.int32(t))
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    print(f"served {B} requests, generated {out.shape[1]} tokens each")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
