"""internvl2-2b — InternViT (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf]  24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The vision tower is a STUB per the assignment:
``input_specs()`` supplies precomputed patch embeddings
(B, 1024, 1024-dim InternViT features), projected into the LM and
prepended to the token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    vision_embed_dim=1024,
    vision_seq=1024,
    rope_theta=1e4,
)
