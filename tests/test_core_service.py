"""The online multi-job path: compiled altruistic passes, live
admission/departure, and the admission-service front end.

Four suites, mirroring ISSUE layers:

- dict == array golden equivalence of the altruistic multi-job pass on
  every builder mix (the compiled passes must be bit-exact against the
  retained dict oracle);
- ``admit_graph(g, at=t)`` differentials: the live-admission run must
  equal a fresh simulation of the merged graph with the new job
  released at ``t`` — exactly, including mid-coflow admission,
  sequential admissions and retire-then-admit;
- admission-queue behaviour: determinism, backlog-bounded queueing and
  rejection, FIFO ordering, the host-kill drill;
- a hypothesis property over random Poisson job streams.
"""
import math

import pytest

from repro.core import MXDAG, Simulator
from repro.core import builders
from repro.core.schedule import AltruisticMultiScheduler
from repro.core.service import AdmissionService, footprint, run_stream


def merged_with(*graphs):
    """Union job graphs the way the oracle simulation needs them."""
    m = MXDAG(graphs[0].name)
    for g in graphs:
        for t in g.tasks.values():
            m.add(t)
        for e in g.edges.values():
            m.add_edge(e.src, e.dst, pipelined=e.pipelined)
    return m


@pytest.fixture(scope="module")
def pool4():
    return builders.pool_cluster(4)


def mr_a():
    return builders.mapreduce("a", 3, 3, hosts_per_side=4,
                              host_prefix="pool", job="a")


def mr_b():
    return builders.mapreduce("b", 4, 2, map_time=0.7, shuffle_time=1.3,
                              hosts_per_side=4, host_prefix="pool",
                              job="b")


def ddl_c():
    return builders.ddl(3, name="c", job="c", worker="pool.M1",
                        ps="pool.R1")


class TestAltruisticGolden:
    """Compiled multi-job pass == dict oracle, every builder mix."""

    @pytest.mark.parametrize("mix", [("mapreduce",), ("ddl",),
                                     ("fanin",), ("layered",), None])
    def test_array_matches_dict(self, mix):
        cl = builders.pool_cluster(4)
        kw = {} if mix is None else {"mix": mix}
        graphs = [g for _, g in builders.poisson_jobs(
            1.5, 8.0, seed=23, n_hosts=4, **kw)]
        assert len(graphs) >= 2
        pa = AltruisticMultiScheduler(
            analytic="array").schedule(graphs, cl)
        pd = AltruisticMultiScheduler(
            analytic="dict").schedule(graphs, cl)
        assert pa.priorities == pd.priorities
        assert set(pa.graph.tasks) == set(pd.graph.tasks)

    def test_memoized_service_loop_matches_cold(self):
        """Warm per-job caches must not change the result."""
        cl = builders.pool_cluster(4)
        graphs = [g for _, g in builders.poisson_jobs(
            1.5, 8.0, seed=29, n_hosts=4)]
        warm = AltruisticMultiScheduler(analytic="array")
        for _ in range(3):
            out = warm.schedule(graphs, cl).priorities
        cold = AltruisticMultiScheduler(
            analytic="array").schedule(graphs, cl).priorities
        assert out == cold


def check_admit(g1, g2, cluster, t, policy="fair", prio=None,
                coflows=None, batch=True):
    """Live admission at ``t`` vs the merged-graph-with-releases oracle,
    exact equality on every observable."""
    rs = Simulator(g1, cluster, policy=policy,
                   priorities={nm: v for nm, v in (prio or {}).items()
                               if nm in g1.tasks},
                   coflows=coflows).resumable(batch=batch)
    rs.admit_graph(g2, at=t,
                   priorities={nm: v for nm, v in (prio or {}).items()
                               if nm in g2.tasks})
    live = rs.run()
    rel = {nm: t for nm in g2.tasks}
    ref = Simulator(merged_with(g1, g2), cluster, policy=policy,
                    priorities=prio or {}, releases=rel,
                    coflows=coflows).run(batch=batch)
    assert live.start == ref.start
    assert live.finish == ref.finish
    assert live.makespan == ref.makespan
    assert live.job_completion == ref.job_completion


class TestAdmitDifferential:
    """admit_graph(g, at=t) == fresh merged sim with releases at t."""

    @pytest.mark.parametrize("t", [0.25, 1.0, 1.7, 2.5])
    def test_mapreduce_pair_fair(self, pool4, t):
        check_admit(mr_a(), mr_b(), pool4, t)

    def test_nobatch_engine(self, pool4):
        check_admit(mr_a(), mr_b(), pool4, 1.7, batch=False)

    def test_mixed_shapes(self, pool4):
        check_admit(mr_a(), ddl_c(), pool4, 0.9)
        check_admit(ddl_c(), mr_a(), pool4, 1.1)

    def test_priority_policy(self, pool4):
        g1, g2 = mr_a(), mr_b()
        prio = {nm: 0.0 for nm in g1.tasks}
        prio.update({nm: 1.0 for nm in g2.tasks})
        check_admit(g1, g2, pool4, 0.8, policy="priority", prio=prio)

    @pytest.mark.parametrize("batch", [True, False])
    def test_mid_coflow_admission(self, pool4, batch):
        g1 = mr_a()
        cof = [set(nm for nm in g1.tasks if ".s" in nm)]
        check_admit(g1, mr_b(), pool4, 1.2, coflows=cof, batch=batch)

    def test_sequential_admissions(self, pool4):
        g1, g2, g3 = mr_a(), mr_b(), ddl_c()
        rs = Simulator(g1, pool4).resumable()
        rs.admit_graph(g2, at=0.6)
        rs.admit_graph(g3, at=1.4)
        live = rs.run()
        rel = {nm: 0.6 for nm in g2.tasks}
        rel.update({nm: 1.4 for nm in g3.tasks})
        ref = Simulator(merged_with(g1, g2, g3), pool4,
                        releases=rel).run()
        assert live.finish == ref.finish
        assert live.job_completion == ref.job_completion

    def test_retire_then_admit(self, pool4):
        g1, g2, g3 = mr_a(), mr_b(), ddl_c()
        rs = Simulator(g1, pool4).resumable()
        rs.admit_graph(g2, at=0.6)
        while rs.unfinished and any(
                rs.finished_at(nm) is None for nm in g1.tasks):
            rs.run_until(rs._ops["peek"]())
        jct_a = max(rs.finished_at(nm) for nm in g1.tasks)
        t3 = max(rs.now, 1.0) + 0.3
        rs.retire_job("a")
        assert all(nm not in rs._idx for nm in g1.tasks)
        rs.admit_graph(g3, at=t3)
        live = rs.run()
        rel = {nm: 0.6 for nm in g2.tasks}
        rel.update({nm: t3 for nm in g3.tasks})
        ref = Simulator(merged_with(g1, g2, g3), pool4,
                        releases=rel).run()
        for nm in list(g2.tasks) + list(g3.tasks):
            assert live.finish[nm] == ref.finish[nm]
        assert jct_a == ref.job_completion["a"]

    def test_poisson_stream_live_vs_merged(self, pool4):
        arr = builders.poisson_jobs(1.2, 6.0, seed=3, n_hosts=4)
        assert len(arr) >= 3
        (t0, g0), rest = arr[0], arr[1:]
        rs = Simulator(g0, pool4).resumable()
        for t, g in rest:
            rs.admit_graph(g, at=t)
        live = rs.run()
        rel = {}
        for t, g in rest:
            rel.update({nm: t for nm in g.tasks})
        ref = Simulator(merged_with(g0, *[g for _, g in rest]), pool4,
                        releases=rel).run()
        assert live.start == ref.start
        assert live.finish == ref.finish
        assert live.job_completion == ref.job_completion

    def test_admit_errors(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        with pytest.raises(ValueError):
            rs.admit_graph(mr_b(), at=0.0)      # no pre-history at t=0
        rs.run_until(1.0)
        with pytest.raises(ValueError):
            rs.admit_graph(mr_b(), at=0.5)      # the past is simulated
        with pytest.raises(ValueError):
            rs.admit_graph(mr_a(), at=1.5)      # job name collision

    def test_retire_errors(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        rs.admit_graph(mr_b(), at=0.5)
        with pytest.raises(RuntimeError):
            rs.retire_job("a")                  # still unfinished
        with pytest.raises(KeyError):
            rs.retire_job("nosuch")
        rs.run()
        with pytest.raises(RuntimeError):       # structural guard
            rs2 = Simulator(mr_a(), pool4).resumable()
            rs2.run_until(0.4)
            rs2.move_task("a.m0", "pool.M2")
            rs2.admit_graph(mr_b(), at=0.6)

    def test_retire_only_job_refused(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        rs.run()
        with pytest.raises(ValueError):
            rs.retire_job("a")


class TestReviveHost:
    """kill_host + revive_host: the transient-failure (reboot) model."""

    def test_kill_then_revive_completes(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        rs.run_until(0.4)
        restarted = rs.kill_host("pool.M1")
        assert restarted
        rs.advance_to(1.0)
        rs.revive_host("pool.M1")
        res = rs.run()
        assert res.makespan > 0
        assert rs.unfinished == 0

    def test_revive_unknown_host(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        with pytest.raises(KeyError):
            rs.revive_host("nosuch.host")

    def test_revive_running_host_refused(self, pool4):
        rs = Simulator(mr_a(), pool4).resumable()
        rs.run_until(0.4)       # mappers running on pool.M*
        with pytest.raises(RuntimeError):
            rs.revive_host("pool.M1")


class TestAdmissionService:
    """The MDBconductor-style front end over the live engine."""

    def test_all_jobs_complete_unbounded(self, pool4):
        arr = builders.poisson_jobs(1.5, 8.0, seed=7, n_hosts=4)
        svc = run_stream(pool4, arr)
        s = svc.summary()
        assert s["completed"] == len(arr)
        assert s["rejected"] == 0
        assert all(j >= 0 for j in svc.jcts().values())

    def test_determinism(self, pool4):
        arr = builders.poisson_jobs(1.5, 8.0, seed=7, n_hosts=4)
        a = run_stream(pool4, arr)
        b = run_stream(pool4, arr)
        assert a.log == b.log
        assert a.jcts() == b.jcts()

    def test_backlog_queueing_and_rejection(self, pool4):
        arr = builders.poisson_jobs(2.0, 8.0, seed=9, n_hosts=4)
        svc = run_stream(pool4, arr, max_backlog=6.0, queue_limit=1)
        s = svc.summary()
        assert s["completed"] + s["rejected"] == len(arr)
        assert s["rejected"] > 0
        verdicts = [e[3] for e in svc.log if e[0] == "submit"]
        assert "queued" in verdicts and "rejected" in verdicts
        # a queued job is admitted at a completion time, deterministic
        admitted_at = {e[2]: e[1] for e in svc.log if e[0] == "admit"}
        for name, st in svc.stats.items():
            if st.finished is not None:
                assert admitted_at[name] >= st.submitted

    def test_oversized_job_rejected_not_queued(self, pool4):
        big = builders.mapreduce("big", 4, 4, map_time=50.0,
                                 hosts_per_side=4, host_prefix="pool",
                                 job="big")
        svc = AdmissionService(pool4, max_backlog=5.0)
        assert svc.submit(big, at=0.5) == "rejected"
        assert svc.stats["big"].status == "rejected"

    def test_fifo_admission_order(self, pool4):
        arr = builders.poisson_jobs(1.5, 6.0, seed=13, n_hosts=4)
        svc = run_stream(pool4, arr, policy="fifo")
        admits = [e[2] for e in svc.log if e[0] == "admit"]
        submits = [e[2] for e in svc.log if e[0] == "submit"]
        assert admits == submits        # unbounded: admit on arrival

    def test_footprint_positive(self):
        cp, work, volume = footprint(mr_a())
        assert cp > 0 and work > 0 and volume > 0

    def test_bad_job_field_refused(self, pool4):
        g = builders.mapreduce("x", 2, 2, hosts_per_side=4,
                               host_prefix="pool", job="not-x")
        svc = AdmissionService(pool4)
        with pytest.raises(ValueError):
            svc.submit(g, at=0.2)

    def test_unknown_host_refused(self, pool4):
        g = builders.mapreduce("x", 2, 2, hosts_per_side=2,
                               host_prefix="elsewhere", job="x")
        svc = AdmissionService(pool4)
        with pytest.raises(KeyError):
            svc.submit(g, at=0.2)

    def test_kill_host_drill_mid_stream(self, pool4):
        arr = builders.poisson_jobs(1.5, 8.0, seed=7, n_hosts=4)
        svc = run_stream(pool4, arr, faults=[(2.0, "pool.M1")],
                         fault_downtime=1.0)
        s = svc.summary()
        assert s["completed"] == len(arr)   # reboot: nothing is lost
        assert len(svc.restarted) > 0
        kinds = [e[0] for e in svc.log]
        assert "kill" in kinds and "revive" in kinds


class TestStreamProperty:
    """Hypothesis over random Poisson job streams."""

    def test_random_streams(self):
        hypothesis = pytest.importorskip(
            "hypothesis",
            reason="hypothesis not installed (pip install -e .[test])")
        from hypothesis import given, settings, strategies as st

        cl = builders.pool_cluster(2)

        @given(seed=st.integers(min_value=0, max_value=10_000),
               rate=st.floats(min_value=0.5, max_value=2.0,
                              allow_nan=False),
               bounded=st.booleans())
        @settings(max_examples=10, deadline=None)
        def prop(seed, rate, bounded):
            arr = builders.poisson_jobs(rate, 4.0, seed=seed, n_hosts=2)
            if not arr:
                return
            kw = {"max_backlog": 15.0, "queue_limit": 2} if bounded \
                else {}
            svc = run_stream(cl, arr, **kw)
            s = svc.summary()
            assert s["completed"] + s["rejected"] == len(arr)
            assert all(j >= -1e-9 for j in svc.jcts().values())
            assert math.isfinite(s["p99_jct"])
            # determinism: a second run reproduces the log exactly
            svc2 = run_stream(cl, arr, **kw)
            assert svc2.log == svc.log

        prop()
