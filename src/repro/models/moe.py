"""Mixture-of-Experts with expert parallelism over the "model" mesh axis.

Design (DESIGN.md §6): activations are sharded over the data axes and
*replicated* over "model"; expert weights are sharded over "model" (EP).
Inside a ``shard_map`` region each model shard:

1. computes the (replicated) router for its data shard's tokens,
2. sorts token→expert assignments and gathers capacity-bounded blocks for
   its *local* experts only,
3. runs the expert FFNs as one batched einsum (MXU-friendly),
4. scatter-adds gated outputs and combines across expert shards with a
   single ``psum`` (or ``psum_scatter`` — a hillclimb lever) that also
   folds in the TP-sharded shared-expert partials.

The psum here is an explicit network MXTask in the training step's MXDAG;
benchmark fig6 and the sync planner reason about it.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

Params = dict


def moe_init(key, cfg: ArchConfig, dtype=jnp.bfloat16) -> Params:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.expert_d_ff
    ks = jax.random.split(key, 7)
    scale = 1.0 / math.sqrt(d)

    def experts(k):
        return (jax.random.normal(k, (E, d, f), jnp.float32) * scale
                ).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, E, dtype=jnp.float32),
        "w_in": experts(ks[1]),
        "w_gate": experts(ks[2]),
        "w_out": (jax.random.normal(ks[3], (E, f, d), jnp.float32)
                  / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * f
        p["shared_in"] = dense_init(ks[4], d, sf, dtype=dtype)
        p["shared_gate"] = dense_init(ks[5], d, sf, dtype=dtype)
        p["shared_out"] = dense_init(ks[6], sf, d, dtype=dtype)
    return p


def _capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(math.ceil(cfg.capacity_factor * n_tokens
                      * cfg.n_experts_per_tok / cfg.n_experts))
    return max(8, -(-c // 8) * 8)          # >=8, multiple of 8


def _local_moe(x2: jax.Array, router: jax.Array, w_in, w_gate, w_out,
               shared, cfg: ArchConfig, ep: int, combine: str,
               in_shard_map: bool = True):
    """Body run per model shard.  x2: [T, d] (this data shard's tokens,
    replicated over model); w_*: local expert slices [E/ep, d|f, f|d]."""
    T, d = x2.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    E_loc = E // ep
    C = _capacity(T, cfg)
    rank = jax.lax.axis_index("model") if in_shard_map else 0

    logits = (x2.astype(jnp.float32) @ router)             # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                   # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # load-balance aux (Switch-style), identical on every model shard
    assign = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], ids].add(1.0 / k)
    f_e = jnp.mean(jax.lax.stop_gradient(assign), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(f_e * p_e)

    # sort assignments by expert id
    flat_ids = ids.reshape(-1)                             # [T*k]
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    sorted_tok = order // k
    sorted_gate = gates.reshape(-1)[order]

    first = rank * E_loc
    bounds = first + jnp.arange(E_loc + 1)
    edges = jnp.searchsorted(sorted_ids, bounds)
    starts, ends = edges[:-1], edges[1:]
    counts = ends - starts

    slot = starts[:, None] + jnp.arange(C)[None, :]        # [E_loc, C]
    valid = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
    slot = jnp.where(valid, slot, 0)
    tok = sorted_tok[slot]                                 # [E_loc, C]
    gate = jnp.where(valid, sorted_gate[slot], 0.0)        # [E_loc, C]

    xe = x2[tok]                                           # [E_loc, C, d]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", xe, w_in)
    ye = jnp.einsum("ecf,efd->ecd", h, w_out)
    ye = ye * gate[..., None].astype(ye.dtype)

    y = jnp.zeros((T, d), ye.dtype).at[tok.reshape(-1)].add(
        ye.reshape(-1, d))

    if shared is not None:
        sh_in, sh_gate, sh_out = shared                    # TP over model
        hs = jax.nn.silu(x2 @ sh_gate) * (x2 @ sh_in)
        y = y + hs @ sh_out                                # partial: psum'd

    if in_shard_map:
        # always combine across the model axis (marks the result invariant
        # over "model" even when ep == 1, where the psum is a no-op)
        if combine == "psum_scatter":
            y = jax.lax.psum_scatter(y, "model", scatter_dimension=0,
                                     tiled=True)
            y = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        else:
            y = jax.lax.psum(y, "model")
    return y, aux[None]


def moe_apply(p: Params, x: jax.Array, cfg: ArchConfig, *,
              mesh: Optional[jax.sharding.Mesh],
              dp_axes: tuple[str, ...] = ("data",),
              combine: str = "psum"):
    """x: [B, S, d] sharded over dp_axes on B.  Returns (y, aux_loss)."""
    B, S, d = x.shape
    shared = None
    has_shared = "shared_in" in p
    if mesh is None or "model" not in mesh.axis_names:
        ep = 1
        shared = ((p["shared_in"], p["shared_gate"], p["shared_out"])
                  if has_shared else None)
        y2, aux = _local_moe(x.reshape(-1, d), p["router"], p["w_in"],
                             p["w_gate"], p["w_out"], shared, cfg, 1,
                             combine, in_shard_map=False)
        return y2.reshape(B, S, d), jnp.mean(aux)

    ep = mesh.shape["model"]
    dp = tuple(a for a in dp_axes if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if B % max(dp_size, 1) != 0:
        dp = ()          # e.g. batch-1 decode: tokens replicated over dp
    xspec = P(dp if dp else None, None, None)
    espec = P("model", None, None)

    def body(x_, router, w_in, w_gate, w_out, *shared_w):
        sh = tuple(shared_w) if shared_w else None
        y2, aux = _local_moe(x_.reshape(-1, d), router, w_in, w_gate,
                             w_out, sh, cfg, ep, combine)
        return y2.reshape(x_.shape), aux

    in_specs = [xspec, P(), espec, espec, espec]
    args = [x, p["router"], p["w_in"], p["w_gate"], p["w_out"]]
    if has_shared:
        in_specs += [P(None, "model"), P(None, "model"), P("model", None)]
        args += [p["shared_in"], p["shared_gate"], p["shared_out"]]

    y, aux = shard_map(
        body, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(xspec, P(dp) if dp else P(None)))(*args)
    return y, jnp.mean(aux)
