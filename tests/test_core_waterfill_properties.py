"""Property-based tests (hypothesis): the vectorized waterfill is
equivalent to the scalar progressive fill.

:func:`repro.core.arraysim.vectorized_waterfill` shares the scalar
:func:`repro.core.simulator.waterfill`'s contract: same freeze *order*
(identical ``(flow, rate)`` sequence ordering — the simulator's replay
machinery depends on it), rates and mutated residuals within EPS (batched
subtraction may associate differently in the last ulp).  Checked here on
random multi-tier fabrics, random flow subsets, and random weighted
groups (the coflow MADD case).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -e .[test])")
np = pytest.importorskip(
    "numpy", reason="vectorized waterfill needs numpy (full lane)")
from hypothesis import given, settings, strategies as st

from repro.core import Topology, flow
from repro.core.arraysim import vectorized_waterfill
from repro.core.simulator import waterfill, waterfill_prep

TOL = 1e-6

racks_st = st.lists(st.integers(min_value=1, max_value=4),
                    min_size=2, max_size=4)
oversub_st = st.floats(min_value=1.0, max_value=8.0,
                       allow_nan=False, allow_infinity=False)
weight_st = st.floats(min_value=0.25, max_value=4.0,
                      allow_nan=False, allow_infinity=False)


def build_case(kind, racks, oversub, n_flows, rng_pairs):
    if kind == "two_tier":
        topo = Topology.two_tier(
            [[f"r{r}h{i}" for i in range(n)]
             for r, n in enumerate(racks)], oversubscription=oversub)
    else:
        topo = Topology.leaf_spine(
            [[f"l{r}h{i}" for i in range(n)]
             for r, n in enumerate(racks)],
            n_spines=2, oversubscription=oversub)
    hosts = topo.hosts()
    paths = {}
    for k in range(n_flows):
        a, b = rng_pairs[k]
        src = hosts[a % len(hosts)]
        dst = hosts[b % len(hosts)]
        if src == dst:
            dst = hosts[(b + 1) % len(hosts)]
            if src == dst:
                continue
        paths[f"f{k}"] = topo.path(src, dst)
    residual = {}
    for p in paths.values():
        for l in p:
            residual.setdefault(l, topo.capacity(l))
    return paths, residual


case_st = st.tuples(
    st.sampled_from(["two_tier", "leaf_spine"]),
    racks_st, oversub_st,
    st.integers(min_value=1, max_value=12),
    st.lists(st.tuples(st.integers(0, 63), st.integers(0, 63)),
             min_size=12, max_size=12),
)


class TestVectorizedEquivalence:
    @given(case=case_st)
    @settings(max_examples=60, deadline=None)
    def test_unit_weights(self, case):
        kind, racks, oversub, n_flows, pairs = case
        paths, residual = build_case(kind, racks, oversub, n_flows, pairs)
        if not paths:
            return
        group = sorted(paths)
        res_s, res_v = dict(residual), dict(residual)
        rates_s, rates_v = {}, {}
        seq_s = waterfill(group, paths, None, res_s, rates_s)
        seq_v = vectorized_waterfill(group, paths, None, res_v, rates_v)
        # identical freeze order, values within EPS
        assert [n for n, _ in seq_v] == [n for n, _ in seq_s]
        for (n1, a1), (n2, a2) in zip(seq_v, seq_s):
            assert a1 == pytest.approx(a2, abs=TOL), n1
        assert rates_v == pytest.approx(rates_s, abs=TOL)
        assert res_v == pytest.approx(res_s, abs=TOL)

    @given(case=case_st,
           ws=st.lists(weight_st, min_size=12, max_size=12))
    @settings(max_examples=60, deadline=None)
    def test_weighted_groups(self, case, ws):
        kind, racks, oversub, n_flows, pairs = case
        paths, residual = build_case(kind, racks, oversub, n_flows, pairs)
        if not paths:
            return
        group = sorted(paths)
        w = {n: ws[i % len(ws)] for i, n in enumerate(group)}
        weight = w.__getitem__
        res_s, res_v = dict(residual), dict(residual)
        rates_s, rates_v = {}, {}
        seq_s = waterfill(group, paths, weight, res_s, rates_s)
        seq_v = vectorized_waterfill(group, paths, weight, res_v, rates_v)
        assert [n for n, _ in seq_v] == [n for n, _ in seq_s]
        for (n1, a1), (n2, a2) in zip(seq_v, seq_s):
            assert a1 == pytest.approx(a2, abs=TOL), n1
        assert rates_v == pytest.approx(rates_s, abs=TOL)
        assert res_v == pytest.approx(res_s, abs=TOL)

    @given(case=case_st)
    @settings(max_examples=20, deadline=None)
    def test_prep_hoisting_is_pure(self, case):
        """waterfill(prep=...) ≡ waterfill() — the cached (sorted group,
        link index) pair must not change results or be mutated."""
        kind, racks, oversub, n_flows, pairs = case
        paths, residual = build_case(kind, racks, oversub, n_flows, pairs)
        if not paths:
            return
        group = sorted(paths)
        prep = waterfill_prep(group, paths)
        snap = (list(prep[0]), {k: list(v) for k, v in prep[1].items()})
        for _ in range(2):          # replay twice off the same prep
            res_a, res_b = dict(residual), dict(residual)
            ra, rb = {}, {}
            assert waterfill(group, paths, None, res_a, ra, prep=prep) \
                == waterfill(group, paths, None, res_b, rb)
            assert ra == rb and res_a == res_b
        assert snap == (list(prep[0]),
                        {k: list(v) for k, v in prep[1].items()})
