"""Architecture configs: the 10 assigned architectures + reduced smoke
variants + the input-shape grid.

Every field is structural (layer counts, dims, flavors); training-time
policy (sharding, remat, optimizer width) lives in ``RunConfig`` so the same
arch can be lowered under different distribution strategies during the perf
hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- MoE ---------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None      # per-expert FFN dim when != d_ff
    moe_layer_period: int = 1           # every k-th layer is MoE
    moe_layer_offset: int = 0           # jamba: MoE at odd indices
    first_dense_layers: int = 0         # deepseek-v3: first 3 layers dense
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention ---------------------------------------------------
    attn_type: str = "gqa"              # gqa | mla
    rope_theta: float = 1e4
    rotary_fraction: float = 1.0        # chatglm3: 0.5 ("RoPE 2d")
    # MLA dims (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP ---------------------------------------------------------
    mlp_type: str = "swiglu"            # swiglu | relu2 | gelu

    # --- SSM (mamba2 / jamba) -----------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_n_groups: int = 1
    ssm_chunk: int = 256

    # --- layer pattern (hybrid) ----------------------------------------
    # repeating pattern of layer kinds; () means all-attention.
    layer_pattern: tuple[str, ...] = ()

    # --- encoder-decoder (whisper) --------------------------------------
    encoder_layers: int = 0
    max_source_positions: int = 0       # whisper: 1500 post-conv frames

    # --- VLM stub (internvl2) -------------------------------------------
    vision_embed_dim: int = 0
    vision_seq: int = 0

    # --- misc ----------------------------------------------------------
    tie_embeddings: bool = False
    mtp: bool = False                   # multi-token prediction head
    norm_eps: float = 1e-5
    sub_quadratic: bool = False         # may run long_500k

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.n_heads and self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def pattern(self) -> tuple[str, ...]:
        return self.layer_pattern or ("attn",) * 1

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def is_moe_layer(self, idx: int) -> bool:
        if not self.n_experts:
            return False
        if idx < self.first_dense_layers:
            return False
        return (idx - self.moe_layer_offset) % self.moe_layer_period == 0

    # parameter counts (for MODEL_FLOPS = 6·N·D roofline term) -----------
    def param_counts(self) -> dict[str, float]:
        """Returns {'total': N, 'active': N_active} (active < total for MoE)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim or 0
        H, K = self.n_heads, self.n_kv_heads

        def attn_params():
            if self.attn_type == "mla":
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                p = 0
                p += d * self.q_lora_rank + self.q_lora_rank * H * qk \
                    if self.q_lora_rank else d * H * qk
                p += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                p += self.kv_lora_rank * H * (self.qk_nope_head_dim
                                              + self.v_head_dim)
                p += H * self.v_head_dim * d
                return p
            return d * H * hd + 2 * d * K * hd + H * hd * d

        def mlp_params(width):
            mult = 3 if self.mlp_type == "swiglu" else 2
            return mult * d * width

        def ssm_params():
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            G, N = self.ssm_n_groups, self.ssm_state
            p = d * (2 * d_in + 2 * G * N + nh)      # in_proj (x,z,B,C,dt)
            p += self.ssm_conv * (d_in + 2 * G * N)  # depthwise conv
            p += 2 * nh + nh                          # A, D, dt_bias
            p += d_in * d                             # out_proj
            return p

        total = active = 0.0
        for i in range(self.n_layers):
            kind = self.pattern[i % len(self.pattern)]
            if kind == "mamba":
                total += ssm_params(); active += ssm_params()
            else:
                total += attn_params(); active += attn_params()
            if self.is_moe_layer(i):
                e = mlp_params(self.expert_d_ff)
                total += d * self.n_experts + self.n_experts * e
                active += d * self.n_experts + self.n_experts_per_tok * e
                if self.n_shared_experts:
                    s = mlp_params(self.n_shared_experts * self.expert_d_ff)
                    total += s; active += s
            else:
                total += mlp_params(ff); active += mlp_params(ff)
        emb = V * d * (1 if self.tie_embeddings else 2)
        total += emb; active += emb
        if self.encoder_layers:
            enc = self.encoder_layers * (attn_params() + mlp_params(ff))
            # decoder cross-attention
            dec_x = self.n_layers * attn_params()
            total += enc + dec_x; active += enc + dec_x
        return {"total": total, "active": active}

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test config of the same family: tiny dims, same structure."""
        pat = self.layer_pattern
        n_layers = max(2, len(pat)) if pat else 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=16 if self.n_heads else None,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            n_experts_per_tok=min(self.n_experts_per_tok, 2),
            moe_d_ff=32 if self.moe_d_ff is not None else None,
            first_dense_layers=min(self.first_dense_layers, 1),
            moe_layer_offset=min(self.moe_layer_offset, 1),
            # no capacity drops at smoke scale: decode must match prefill
            capacity_factor=16.0,
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            encoder_layers=min(self.encoder_layers, 2),
            max_source_positions=16 if self.max_source_positions else 0,
            vision_embed_dim=32 if self.vision_embed_dim else 0,
            vision_seq=8 if self.vision_seq else 0,
        )


# ----------------------------------------------------------------------
# input shapes (assigned grid)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (skip noted in DESIGN.md §4)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        shapes.append("long_500k")
    return shapes


# ----------------------------------------------------------------------
# run-time policy (distribution / numerics) — hillclimb lever, not arch
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunConfig:
    fsdp: bool = False            # shard params/opt-state over data axis
    batch_axes: str = "dp"        # "dp" | "all": small models (no TP need)
                                  # shard batch over every mesh axis
    remat: bool = True            # scan-level activation checkpointing
    opt_8bit: bool = False        # int8 Adam moments (error-bounded)
    grad_compression: bool = False  # fp8 error-feedback gradient allreduce
    sync_mode: str = "barrier"    # barrier (baseline) | bucketed
                                  # (layer-wise overlap per the MXDAG plan)
    moe_combine: str = "psum"     # psum | psum_scatter
    attn_impl: str = "xla_flash"  # xla_flash | xla | pallas
    ssm_chunk: int = 0            # override ArchConfig.ssm_chunk (0 = keep)
    seq_shard: bool = False       # shard activations' seq dim over "model"
                                  # (SP for attention-free archs)
    microbatches: int = 1
    logits_fp32: bool = True
